//! The workflow instance runtime: wires TaskManager + RS + TaskWorkers +
//! RD into a thread group around one RDMA ring endpoint.
//!
//! Thread layout per instance:
//! - **control** (TaskManager): polls the [`ControlPlane`] for assignment
//!   changes, reconfigures the queue / executor binding / RD hops,
//!   reports windowed utilization.
//! - **rs** (RequestScheduler): drains the ring buffer into the
//!   [`SchedQueue`] per the active mode, tagging each arrival with its
//!   [`crate::client::Priority`] from the set's
//!   [`crate::client::RequestTracker`], and dropping messages whose
//!   request was cancelled or whose deadline already passed (publishing
//!   a tombstone instead).
//! - **worker-i** (TaskWorkers): fetch → SLO check → execute app logic →
//!   SLO re-check → deliver. The re-check drops results whose deadline
//!   expired *during* execution — stage work past its deadline never
//!   reaches the next ring.
//!
//! In Collaboration Mode every worker executes the broadcast request (the
//! TP/PP ranks of §4.4) but only worker 0 delivers the aggregated result
//! (§4.5: "partial results from all workers are aggregated into a single
//! consolidated output before delivery").

use super::{Assignment, ControlPlane, Delivery, ResultDeliver, SchedQueue, StageRole};
use crate::batch::{BatchAssembler, MicroBatch};
use crate::cache::{ArtifactCache, Flight};
use crate::client::{InFlightVerdict, RequestTracker};
use crate::config::SchedMode;
use crate::db::{EntryKind, MemDb};
use crate::metrics::{Counter, Histogram, UtilizationWindow};
use crate::rdma::{Fabric, RegionId};
use crate::ringbuf::RingConfig;
use crate::runtime::{ExecutorPool, StageExecutor};
use crate::transport::{Payload, RdmaEndpoint, StageId, WorkflowMessage};
use crate::util::{Clock, NodeId, Uid};
use crate::workflow::AppLogic;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Instance construction parameters.
pub struct InstanceConfig {
    pub node: NodeId,
    pub ring: RingConfig,
    /// TaskManager poll period.
    pub control_poll: Duration,
    /// Utilization window for NM reporting.
    pub util_window: Duration,
    /// Max workers this instance can spin up (threads are created up
    /// front; the assignment's `workers` count activates a subset).
    pub max_workers: usize,
    /// Write per-hop recovery checkpoints (the wset enables this only
    /// when `nm.instance_timeout_ms` turns the failure detector on —
    /// without it nothing ever replays them, so the default is off,
    /// mirroring the detector's own default).
    pub checkpointing: bool,
    /// SchedQueue aging guard (`batch.max_starvation_ms`): a queued
    /// message older than this is promoted past higher priority bands.
    /// Zero (the default) keeps strict highest-band-first.
    pub max_starvation: Duration,
    /// Eager/rendezvous cutover for downstream deliveries
    /// (`rdma.rendezvous_threshold_bytes`; 0 = eager only).
    pub rendezvous_threshold: usize,
    /// The set's artifact cache: workers consult it before
    /// `execute`/`execute_batch` on enabled stages (hit → skip
    /// execution, forward the cached output through the normal delivery
    /// path). None (the default, and whenever the deployment has no
    /// `cache` config block) keeps the execute loop byte-identical.
    pub cache: Option<Arc<ArtifactCache>>,
    /// Flight-recorder hook for distributed tracing (one per instance,
    /// from [`crate::trace::Tracer::hook`]). None (the default, and
    /// whenever the deployment has no `trace` config block) keeps the
    /// whole data plane byte-identical — not a single trace branch is
    /// taken.
    pub trace: Option<crate::trace::TraceHook>,
}

impl Default for InstanceConfig {
    fn default() -> Self {
        Self {
            node: NodeId(0),
            ring: RingConfig::default(),
            control_poll: Duration::from_millis(5),
            util_window: Duration::from_millis(500),
            max_workers: 4,
            checkpointing: false,
            max_starvation: Duration::ZERO,
            rendezvous_threshold: 0,
            cache: None,
            trace: None,
        }
    }
}

/// Live instance statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct InstanceStats {
    pub processed: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub errors: u64,
    /// In-flight work dropped by the SLO checks (cancelled requests and
    /// deadline-expired stage work).
    pub sla_dropped: u64,
}

/// How many 1 ms park-and-requeue rounds a message may spend on a
/// roleless instance before it is declared lost. The promotion race this
/// protects against (a recovery replay lands before the control thread
/// applies the new assignment) resolves within one or two control polls
/// (~5 ms); 100 rounds is a generous bound that still terminates stray
/// traffic to a persistently idle instance.
const MAX_ROLELESS_REQUEUES: u32 = 100;

/// Backstop bound on the parked-message counter map (entries for
/// messages that vanished mid-park, e.g. a queue reconfigure, would
/// otherwise accumulate).
const MAX_PARKED_ENTRIES: usize = 4096;

struct Shared {
    node: NodeId,
    queue: Arc<SchedQueue>,
    role: RwLock<Option<StageRole>>,
    version: AtomicU64,
    executor: RwLock<Option<StageExecutor>>,
    deliver: Mutex<ResultDeliver>, // lint: lock-rank(deliver, 65)
    tracker: Arc<RequestTracker>,
    util: UtilizationWindow,
    /// Micro-batch former + adaptive window (one per instance, shared
    /// by the worker pool; active only while the role carries a
    /// [`crate::batch::BatchPolicy`]).
    assembler: BatchAssembler,
    /// Batching metrics (from the set registry the tracker carries):
    /// formed-batch size / formation-wait histograms and the
    /// formed-vs-bypassed counters.
    batch_size_h: Arc<Histogram>,
    batch_wait_h: Arc<Histogram>,
    batches_executed: Arc<Counter>,
    batch_bypass: Arc<Counter>,
    /// Requeue counts for messages parked while the instance has no
    /// role (shared across workers so the patience bound does not
    /// multiply by worker count).
    parked: Mutex<std::collections::HashMap<Uid, u32>>, // lint: lock-rank(parked, 66)
    /// The set runs a recovery sweep (mirrors `checkpointing`): messages
    /// the data plane cannot progress are handed to it for checkpoint
    /// replay instead of being failed outright.
    recovery_enabled: bool,
    /// Per-stage artifact cache (None = cache off, execute loop
    /// unchanged).
    cache: Option<Arc<ArtifactCache>>,
    /// Tracing hook (None = tracing off, every record site compiles to
    /// a skipped `if let`).
    trace: Option<crate::trace::TraceHook>,
    shutdown: AtomicBool,
    /// Crash injection (chaos testing): when set, every thread goes
    /// dormant — no heartbeats, no ring drains, no stage work — exactly
    /// as if the process died, but still joinable on shutdown.
    crashed: Arc<AtomicBool>,
    processed: AtomicU64,
    errors: AtomicU64,
    sla_dropped: AtomicU64,
}

impl Shared {
    /// Record one trace event when tracing is on; free when it is off.
    #[inline]
    fn trace(&self, uid: Uid, stage: Option<u32>, kind: crate::trace::EventKind) {
        if let Some(t) = &self.trace {
            t.record(uid, stage, kind);
        }
    }

    /// Drop a request the control plane declared dead: publish the
    /// matching tombstone and count it. The tracker entry is
    /// deliberately **kept**: in Collaboration Mode the other ranks
    /// still hold broadcast copies and must see the same verdict, and a
    /// cancelled UID must keep dropping late-arriving messages. The
    /// entry is released when the client's handle consumes the
    /// tombstone, or by the housekeeper's tracker sweep.
    fn drop_for(&self, uid: Uid, verdict: InFlightVerdict) {
        let kind = match verdict {
            InFlightVerdict::Cancelled => EntryKind::Cancelled,
            InFlightVerdict::DeadlineExceeded => EntryKind::DeadlineExceeded,
            InFlightVerdict::Failed => EntryKind::Failed,
            InFlightVerdict::Proceed => return,
        };
        self.deliver.lock().unwrap().tombstone(uid, kind);
        self.sla_dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Declare `uid` lost (no downstream capacity, or stranded on a
    /// roleless instance) — a case the recovery sweep can never reach
    /// because this instance's ring owner is alive. Tracked requests get
    /// a terminal `Failed` tombstone; an already-cancelled or
    /// deadline-expired request keeps its own verdict (and tombstone
    /// kind); untracked messages keep the paper's silent-drop semantics.
    fn fail_for(&self, uid: Uid) {
        match self.tracker.verdict(uid) {
            InFlightVerdict::Proceed => {
                if self.tracker.mark_failed(uid) {
                    self.deliver.lock().unwrap().tombstone(uid, EntryKind::Failed);
                }
            }
            verdict => self.drop_for(uid, verdict),
        }
    }

    /// A message the data plane cannot progress (role changed mid-queue
    /// during a donor steal, persistently roleless, downstream refused):
    /// hand the request to the recovery sweep for a checkpoint replay
    /// when the subsystem is on — these requests can still complete —
    /// else fail it terminally rather than strand the client.
    fn strand_or_fail(&self, uid: Uid) {
        if self.recovery_enabled && self.tracker.strand(uid) {
            return; // the sweep replays it from its checkpoint
        }
        self.fail_for(uid);
    }
}

/// Remote-control switch for crash injection: lets the set's chaos
/// driver (housekeeper) kill an instance it does not own. Cloneable and
/// cheap; killing is idempotent.
#[derive(Clone)]
pub struct CrashHandle {
    crashed: Arc<AtomicBool>,
}

impl CrashHandle {
    /// Simulate an instance crash: all threads go dormant immediately.
    pub fn kill(&self) {
        self.crashed.store(true, Ordering::SeqCst);
    }

    /// True once the instance was killed.
    pub fn is_crashed(&self) -> bool {
        self.crashed.load(Ordering::SeqCst)
    }
}

/// A running workflow instance.
pub struct Instance {
    shared: Arc<Shared>,
    region_id: RegionId,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Instance {
    /// Spawn the instance's thread group.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cfg: InstanceConfig,
        fabric: &Fabric,
        control: Arc<dyn ControlPlane>,
        logic: Arc<dyn AppLogic>,
        pool: ExecutorPool,
        dbs: Vec<Arc<MemDb>>,
        tracker: Arc<RequestTracker>,
        clock: Arc<dyn Clock>,
    ) -> Self {
        let mut endpoint = RdmaEndpoint::new(fabric, cfg.ring);
        let region_id = endpoint.region_id();
        let queue =
            SchedQueue::with_aging(SchedMode::Individual, cfg.max_workers, cfg.max_starvation);
        let mut rd = ResultDeliver::new(fabric.clone(), dbs);
        rd.set_checkpointing(cfg.checkpointing);
        rd.set_rendezvous_threshold(cfg.rendezvous_threshold);
        let metrics = tracker.metrics().clone();
        // Ring-path observability: every downstream push this instance
        // performs lands in the set's ring_* counters; the endpoint
        // accounts the receive side of the payload plane.
        let ring_metrics = crate::transport::RingMetrics::from_registry(&metrics);
        endpoint.set_metrics(ring_metrics.clone());
        rd.set_metrics(ring_metrics);
        if let Some(c) = &cfg.cache {
            // Terminal stores seed the workflow-level admission tier.
            rd.set_cache(c.clone());
        }
        if let Some(t) = &cfg.trace {
            // RD and the receive endpoint record their own hops
            // (checkpoints, downstream pushes, rendezvous pulls) into
            // the same per-instance flight recorder.
            rd.set_trace(t.clone());
            endpoint.set_trace(t.clone());
        }
        let shared = Arc::new(Shared {
            node: cfg.node,
            queue: queue.clone(),
            role: RwLock::new(None),
            version: AtomicU64::new(u64::MAX),
            executor: RwLock::new(None),
            deliver: Mutex::new(rd),
            tracker,
            util: UtilizationWindow::new(clock, cfg.util_window.as_nanos() as u64),
            assembler: BatchAssembler::new(),
            batch_size_h: metrics.histogram("batch_size"),
            batch_wait_h: metrics.histogram("batch_wait_ns"),
            batches_executed: metrics.counter("batches_executed"),
            batch_bypass: metrics.counter("batch_bypass"),
            parked: Mutex::new(std::collections::HashMap::new()),
            recovery_enabled: cfg.checkpointing,
            cache: cfg.cache,
            trace: cfg.trace,
            shutdown: AtomicBool::new(false),
            crashed: Arc::new(AtomicBool::new(false)),
            processed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            sla_dropped: AtomicU64::new(0),
        });

        let mut threads = Vec::new();

        // --- control thread (TaskManager) ---
        {
            let shared = shared.clone();
            let pool = pool.clone();
            let poll = cfg.control_poll;
            threads.push(std::thread::spawn(move || {
                while !shared.shutdown.load(Ordering::SeqCst) {
                    // A crashed instance stops heartbeating (the
                    // utilization report doubles as liveness, §8.2) —
                    // this is what the NM's failure detector observes.
                    if shared.crashed.load(Ordering::SeqCst) {
                        std::thread::sleep(poll);
                        continue;
                    }
                    let a: Assignment = control.get_assignment(shared.node);
                    if a.version != shared.version.load(Ordering::SeqCst) {
                        Self::apply_assignment(&shared, &pool, &a);
                        shared.version.store(a.version, Ordering::SeqCst);
                    }
                    let util = shared.util.value();
                    control.report_utilization(shared.node, util);
                    // Batching stages: feed the utilization sample into
                    // the adaptive controller (idle → shrink the window
                    // for latency) and export the effective window so
                    // §8.2 reallocation and batch sizing don't fight.
                    let policy = shared
                        .role
                        .read()
                        .unwrap()
                        .as_ref()
                        .and_then(|r| r.batch.as_ref().map(|p| (p.adaptive, p.max_wait)));
                    if let Some((adaptive, max_wait)) = policy {
                        let max_wait_us = max_wait.as_micros() as u64;
                        let window_us = if adaptive {
                            shared.assembler.observe_utilization(util);
                            // 0 = "no batch formed yet" (unset) — the
                            // stage still coalesces on purpose, so
                            // report the policy cap, never 0 (the NM
                            // reads 0 as "not batching").
                            match shared.assembler.window_us() {
                                0 => max_wait_us,
                                w => w,
                            }
                        } else {
                            // Static window: the configured cap *is* the
                            // effective window.
                            max_wait_us
                        };
                        control.report_batch_window(shared.node, window_us);
                    }
                    std::thread::sleep(poll);
                }
            }));
        }

        // --- RS thread ---
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || {
                // Batched drain: a coalesced arrival burst (one
                // `push_many` from an upstream batch) is pulled out of
                // the ring in one header-read round, so the batch
                // assembler sees its members together instead of one
                // per 100 µs poll.
                let mut inbox: Vec<WorkflowMessage> = Vec::new();
                while !shared.shutdown.load(Ordering::SeqCst) {
                    if shared.crashed.load(Ordering::SeqCst) {
                        // Crashed: the ring fills and messages strand —
                        // the recovery sweep replays them elsewhere.
                        std::thread::sleep(Duration::from_millis(5));
                        continue;
                    }
                    if endpoint.recv_many(64, &mut inbox) == 0 {
                        std::thread::sleep(Duration::from_micros(100));
                        continue;
                    }
                    for msg in inbox.drain(..) {
                        let uid = msg.header.uid;
                        match shared.tracker.verdict(uid) {
                            InFlightVerdict::Proceed => {
                                let prio = shared.tracker.priority_of(uid);
                                shared.trace(
                                    uid,
                                    Some(msg.header.stage.0),
                                    crate::trace::EventKind::Enqueued,
                                );
                                shared.queue.dispatch(msg, prio);
                            }
                            // Cancelled / past-deadline arrivals never
                            // reach a worker.
                            verdict => shared.drop_for(uid, verdict),
                        }
                    }
                }
            }));
        }

        // --- worker threads ---
        for widx in 0..cfg.max_workers {
            let shared = shared.clone();
            let logic = logic.clone();
            threads.push(std::thread::spawn(move || {
                Self::worker_loop(&shared, &*logic, widx);
            }));
        }

        Self { shared, region_id, threads }
    }

    fn apply_assignment(shared: &Arc<Shared>, pool: &ExecutorPool, a: &Assignment) {
        match &a.role {
            Some(role) => {
                let exec = pool.get(&role.stage_name).cloned();
                *shared.executor.write().unwrap() = exec;
                // A mode/shape change drains the queue; strand the
                // displaced work for the recovery sweep (route-only
                // updates preserve it — see SchedQueue::reconfigure).
                for m in shared.queue.reconfigure(role.mode, role.workers) {
                    shared.strand_or_fail(m.header.uid);
                }
                shared
                    .deliver
                    .lock()
                    .unwrap()
                    .set_routes(role.routes.clone());
                *shared.role.write().unwrap() = Some(role.clone());
            }
            None => {
                // Parked in the idle pool (§8.2): no executor, no hops.
                // Strand pending work (one copy per request — CM
                // broadcast copies are deduplicated) so it reaches the
                // recovery path instead of circulating, and normalize
                // the queue so later stray arrivals hold single copies.
                *shared.executor.write().unwrap() = None;
                *shared.role.write().unwrap() = None;
                for m in shared.queue.drain_pending() {
                    shared.strand_or_fail(m.header.uid);
                }
                let _ = shared.queue.reconfigure(SchedMode::Individual, 1);
            }
        }
    }

    /// The reserved fast lane of a batching stage: with a batch policy
    /// on a multi-worker IM stage, worker 0 serves **only** the bypass
    /// classes (band mask), so a bypassing Interactive arrival never
    /// finds every worker mid-batch — without it, bypass would only skip
    /// formation, not the head-of-line wait behind in-flight batches.
    /// Returns `None` (no reservation) when nothing bypasses, when the
    /// stage runs a single worker (reserving it would disable the stage)
    /// or when batching is off.
    fn lane_mask(shared: &Shared, widx: usize) -> Option<[bool; 3]> {
        if widx != 0 {
            return None;
        }
        let r = shared.role.read().unwrap();
        let role = r.as_ref()?;
        let policy = role.batch.as_ref()?;
        if role.mode != SchedMode::Individual || role.workers <= 1 {
            return None;
        }
        let mask = [
            policy.bypasses(crate::client::Priority::Interactive),
            policy.bypasses(crate::client::Priority::Standard),
            policy.bypasses(crate::client::Priority::Batch),
        ];
        mask.iter().any(|b| *b).then_some(mask)
    }

    fn worker_loop(shared: &Arc<Shared>, logic: &dyn AppLogic, widx: usize) {
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if shared.crashed.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            let fetched = match Self::lane_mask(shared, widx) {
                Some(mask) => shared.queue.fetch_from(widx, mask, Duration::from_millis(20)),
                None => shared.queue.fetch(widx, Duration::from_millis(20)),
            };
            let Some(msg) = fetched else {
                continue;
            };
            shared.trace(
                msg.header.uid,
                Some(msg.header.stage.0),
                crate::trace::EventKind::Dequeued,
            );
            let (role, exec) = {
                let r = shared.role.read().unwrap();
                let e = shared.executor.read().unwrap();
                match (r.clone(), e.clone()) {
                    (Some(r), Some(e)) => (r, e),
                    _ => {
                        // No role (yet): the control thread may be
                        // mid-apply of a promotion and recovery replays
                        // race it — park the message back instead of
                        // dropping it, up to a patience bound. In CM the
                        // queue holds one broadcast copy per worker and
                        // a re-dispatch would re-broadcast: only rank 0
                        // parks its copy, siblings drop theirs.
                        if shared.queue.mode() == SchedMode::Collaboration && widx != 0
                        {
                            continue;
                        }
                        let uid = msg.header.uid;
                        let exhausted = {
                            let mut parked = shared.parked.lock().unwrap();
                            if parked.len() > MAX_PARKED_ENTRIES {
                                parked.clear();
                            }
                            let n = parked.entry(uid).or_insert(0);
                            *n += 1;
                            let exhausted = *n > MAX_ROLELESS_REQUEUES;
                            if exhausted {
                                parked.remove(&uid);
                            }
                            exhausted
                        };
                        if exhausted {
                            // Persistently roleless: the message will
                            // never execute here — hand it to the
                            // recovery sweep (or fail terminally).
                            shared.strand_or_fail(uid);
                            continue;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                        let prio = shared.tracker.priority_of(uid);
                        shared.queue.dispatch(msg, prio);
                        continue;
                    }
                }
            };
            {
                let mut parked = shared.parked.lock().unwrap();
                if !parked.is_empty() {
                    parked.remove(&msg.header.uid);
                }
            }
            // In CM every worker holds a broadcast copy; rank 0 is the
            // one that delivers, so it alone accounts SLO drops and
            // strands displaced work.
            let lead = role.mode != SchedMode::Collaboration || widx == 0;
            let uid = msg.header.uid;
            // Stage sanity: a message that survived an idle-parking
            // requeue (or drained into a donor-stolen instance) must not
            // execute under a different stage role — its request can
            // still complete via a checkpoint replay (routine donor
            // steals must not turn into request failures), so strand it
            // for the recovery sweep rather than computing garbage.
            // Applies to every app the role serves: shared apps alias at
            // the same stage index (§8.3 `share_stage` usage — the
            // worker stamps `role.stage_index + 1` on every output, so
            // same-index aliasing is already a standing assumption), and
            // a message for an app with no route here could never be
            // delivered after execution anyway.
            let served = msg.header.app == role.app
                || role.routes.iter().any(|(a, _)| *a == msg.header.app);
            if !served || msg.header.stage.0 != role.stage_index {
                if lead {
                    shared.strand_or_fail(uid);
                }
                continue;
            }
            // SLO check before spending compute (the request may have
            // been cancelled / expired while queued).
            match shared.tracker.verdict(uid) {
                InFlightVerdict::Proceed => {}
                verdict => {
                    if lead {
                        shared.drop_for(uid, verdict);
                    }
                    continue;
                }
            }
            // ---- micro-batch formation (IM stages carrying a policy;
            // everything else is a batch of one, taking exactly the
            // single-request path below). The reserved fast lane
            // (worker 0, see `lane_mask`) only ever fetches bypass
            // classes; `fast_lane` here just closes the race where a
            // role change lands between its fetch and this point.
            let batch = match &role.batch {
                Some(policy) if role.mode == SchedMode::Individual => {
                    // Mirrors `lane_mask`: worker 0 is only a bypass
                    // lane when a reservation is actually active — with
                    // nothing bypassing, it batches like everyone else.
                    let fast_lane =
                        widx == 0 && role.workers > 1 && policy.any_bypass();
                    let b = shared.assembler.assemble(
                        msg,
                        policy,
                        &shared.queue,
                        &shared.tracker,
                        fast_lane,
                    );
                    if b.bypassed {
                        shared.batch_bypass.inc();
                    } else {
                        shared.batches_executed.inc();
                        shared.batch_size_h.record(b.len() as u64);
                        shared.batch_wait_h.record(b.wait.as_nanos() as u64);
                    }
                    if shared.trace.is_some() {
                        let kind = crate::trace::EventKind::BatchFormed {
                            size: b.len().min(u16::MAX as usize) as u16,
                            bypassed: b.bypassed,
                        };
                        for m in &b.members {
                            shared.trace(m.header.uid, Some(role.stage_index), kind);
                        }
                    }
                    b
                }
                _ => MicroBatch::single(msg, false),
            };
            // Re-check members picked up during formation: a request
            // cancelled / expired while the batch formed is dropped here
            // without poisoning the rest (the first member was checked
            // above, before formation).
            let mut members = Vec::with_capacity(batch.len());
            for (i, m) in batch.members.into_iter().enumerate() {
                if i == 0 {
                    members.push(m);
                    continue;
                }
                match shared.tracker.verdict(m.header.uid) {
                    InFlightVerdict::Proceed => members.push(m),
                    verdict => {
                        if lead {
                            shared.drop_for(m.header.uid, verdict);
                        }
                    }
                }
            }
            for m in &members {
                shared.tracker.note_stage(m.header.uid, role.stage_index);
            }
            // Per-stage artifact cache, lead worker only (in CM every
            // rank holds a broadcast copy; the cached output IS the
            // aggregated result, so rank 0 — the one that delivers — is
            // the one whose execution a hit may skip; sibling ranks run
            // unchanged and their outputs are discarded as always).
            let cache = if lead {
                shared
                    .cache
                    .as_ref()
                    .filter(|c| c.stage_enabled(&role.stage_name))
            } else {
                None
            };
            let results = match cache {
                Some(cache) => Self::execute_with_cache(
                    shared, logic, &exec, &role, cache, &members,
                ),
                None => {
                    shared.util.busy();
                    for m in &members {
                        shared.trace(
                            m.header.uid,
                            Some(role.stage_index),
                            crate::trace::EventKind::ExecBegin,
                        );
                    }
                    let r = logic.execute_batch(&role.stage_name, &exec, &members);
                    for m in &members {
                        shared.trace(
                            m.header.uid,
                            Some(role.stage_index),
                            crate::trace::EventKind::ExecEnd,
                        );
                    }
                    // Utilization is weighted per *request*, not per
                    // invocation: an amortized batch must report the
                    // demand it absorbed or the NM under-estimates load
                    // on batching stages.
                    shared.util.idle_n(members.len() as u32);
                    r
                }
            };
            // A crash that fired mid-execution kills the output too — a
            // dead process delivers nothing.
            if shared.crashed.load(Ordering::SeqCst) {
                continue;
            }
            // Defensive: `execute_batch` owes one result per member. A
            // custom logic that breaks the contract must not leave the
            // unmatched tail in limbo (no result, no tombstone — the
            // client would hang), so the tail errors out and reaches the
            // recovery sweep / a terminal state like any failed member.
            if results.len() < members.len() {
                for m in &members[results.len()..] {
                    shared.errors.fetch_add(1, Ordering::Relaxed);
                    if lead {
                        shared.strand_or_fail(m.header.uid);
                    }
                }
            }
            let mut outs: Vec<WorkflowMessage> = Vec::with_capacity(members.len());
            for (m, result) in members.iter().zip(results) {
                let uid = m.header.uid;
                match result {
                    Ok(payload) => {
                        shared.processed.fetch_add(1, Ordering::Relaxed);
                        // CM: all workers computed (TP ranks); rank 0
                        // delivers the aggregated output.
                        if !lead {
                            continue;
                        }
                        // SLO re-check: the deadline may have expired
                        // during execution — drop this member's output
                        // instead of forwarding work that can no longer
                        // meet its SLO (its batchmates are unaffected).
                        match shared.tracker.verdict(uid) {
                            InFlightVerdict::Proceed => {}
                            verdict => {
                                shared.drop_for(uid, verdict);
                                continue;
                            }
                        }
                        outs.push(WorkflowMessage {
                            header: crate::transport::MessageHeader {
                                stage: StageId(role.stage_index + 1),
                                ..m.header
                            },
                            payload,
                        });
                    }
                    Err(_) => {
                        shared.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            if outs.is_empty() {
                continue;
            }
            // Coalesced delivery: one hop choice + one push pass for the
            // whole batch (identical to `deliver` for a batch of one).
            let deliveries = shared.deliver.lock().unwrap().deliver_batch(&outs);
            for (out, delivery) in outs.iter().zip(deliveries) {
                let uid = out.header.uid;
                match delivery {
                    // Tell the control plane where the request went — if
                    // that instance dies, the recovery sweep finds the
                    // request by this location.
                    Delivery::Sent(region) => {
                        shared.trace(
                            uid,
                            Some(role.stage_index),
                            crate::trace::EventKind::Delivered,
                        );
                        shared.tracker.note_location(uid, region);
                    }
                    Delivery::Stored => {
                        // Terminal store: the request's result reached
                        // the DB for the client to fetch — this is the
                        // data plane's "done" moment.
                        shared.trace(
                            uid,
                            Some(role.stage_index),
                            crate::trace::EventKind::Delivered,
                        );
                        shared.trace(
                            uid,
                            None,
                            crate::trace::EventKind::Terminal {
                                verdict: crate::trace::Verdict::Done,
                            },
                        );
                    }
                    Delivery::Dropped => {
                        // No downstream capacity (the next stage lost
                        // every instance, or its ring refused the
                        // write). A transient full ring can still clear
                        // — strand for a checkpoint replay; otherwise a
                        // terminal tombstone beats a silent §9 loss the
                        // client would wait out.
                        shared.strand_or_fail(uid);
                    }
                }
            }
        }
    }

    /// How long a single-flight follower waits for its leader before
    /// falling back to computing the stage itself. Generous relative to
    /// any stage cost; coalescing is an optimization, never a liveness
    /// dependency.
    const FLIGHT_WAIT: Duration = Duration::from_secs(10);

    /// Batch execution through the artifact cache:
    ///
    /// 1. **Lookup** per member — a hit skips execution entirely and the
    ///    cached bytes decode into this member's result.
    /// 2. **Coalesce** — identical keys inside the batch execute once
    ///    (later members copy the first's result); identical misses
    ///    racing across workers join the first worker's single-flight.
    /// 3. **Execute** only the remaining leaders through the normal
    ///    `execute_batch` path (utilization accounting unchanged for the
    ///    executed subset; hits report no busy time — no GPU was spent).
    /// 4. **Fill + publish**: each leader's successful output is encoded
    ///    once; the cache fill (first-writer-wins, skipped when the
    ///    request was cancelled or expired mid-execution so a doomed
    ///    request never poisons the cache) and the follower wake share
    ///    that buffer. Errors abandon the flight — followers recompute.
    ///
    /// Leaders always complete (or abandon) their own flights **before**
    /// any follower wait begins, so two workers cross-following each
    /// other's keys cannot deadlock.
    ///
    /// Returns one result per member, in order, like `execute_batch`.
    fn execute_with_cache(
        shared: &Arc<Shared>,
        logic: &dyn AppLogic,
        exec: &StageExecutor,
        role: &StageRole,
        cache: &Arc<ArtifactCache>,
        members: &[WorkflowMessage],
    ) -> Vec<anyhow::Result<Payload>> {
        enum Slot {
            /// Cache hit, already decoded.
            Ready(Payload),
            /// Executes in this invocation (leader or uncoalesced miss).
            Exec,
            /// Same key as an earlier member: copy its result.
            Dup(usize),
            /// Another worker is computing this key: wait on its flight.
            Follow(crate::cache::FlightWait),
        }
        let n = members.len();
        let mut keys = Vec::with_capacity(n);
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        let mut guards: Vec<Option<crate::cache::FlightGuard>> =
            (0..n).map(|_| None).collect();
        let mut first_by_key: std::collections::HashMap<u128, usize> =
            std::collections::HashMap::new();
        for (i, m) in members.iter().enumerate() {
            let key = cache.key_for(m.header.app, &role.stage_name, &m.payload);
            keys.push(key);
            if let Some(bytes) = cache.lookup(&role.stage_name, key) {
                if let Ok(p) = Payload::decode(&bytes) {
                    shared.trace(
                        m.header.uid,
                        Some(role.stage_index),
                        crate::trace::EventKind::CacheHit,
                    );
                    slots.push(Slot::Ready(p));
                    continue;
                }
                // Undecodable cached bytes (should not happen — entries
                // are validated encodings): recompute rather than fail.
            }
            shared.trace(
                m.header.uid,
                Some(role.stage_index),
                crate::trace::EventKind::CacheMiss,
            );
            if let Some(&j) = first_by_key.get(&key.0) {
                slots.push(Slot::Dup(j));
                continue;
            }
            first_by_key.insert(key.0, i);
            match cache.begin_flight(key) {
                Flight::Leader(g) => {
                    guards[i] = Some(g);
                    slots.push(Slot::Exec);
                }
                Flight::Follower(w) => slots.push(Slot::Follow(w)),
            }
        }

        // Execute the leaders as one (sub-)batch.
        let exec_idx: Vec<usize> = slots
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, Slot::Exec))
            .map(|(i, _)| i)
            .collect();
        let exec_results = if exec_idx.is_empty() {
            Vec::new()
        } else {
            let subset: Vec<WorkflowMessage> =
                exec_idx.iter().map(|&i| members[i].clone()).collect();
            shared.util.busy();
            for m in &subset {
                shared.trace(
                    m.header.uid,
                    Some(role.stage_index),
                    crate::trace::EventKind::ExecBegin,
                );
            }
            let r = logic.execute_batch(&role.stage_name, exec, &subset);
            for m in &subset {
                shared.trace(
                    m.header.uid,
                    Some(role.stage_index),
                    crate::trace::EventKind::ExecEnd,
                );
            }
            shared.util.idle_n(subset.len() as u32);
            r
        };

        // Fill + publish each leader's output, then place its result.
        let mut results: Vec<Option<anyhow::Result<Payload>>> =
            (0..n).map(|_| None).collect();
        let mut it = exec_results.into_iter();
        for &i in &exec_idx {
            let res = it.next().unwrap_or_else(|| {
                Err(anyhow::anyhow!("stage logic returned no result for batch member"))
            });
            let guard = guards[i].take();
            if let Ok(payload) = &res {
                let bytes: Arc<[u8]> = payload.encode().into();
                if shared.tracker.verdict(members[i].header.uid)
                    == InFlightVerdict::Proceed
                {
                    cache.fill(keys[i], &bytes);
                }
                if let Some(g) = guard {
                    g.complete(bytes);
                }
            }
            // Err: `guard` drops here un-completed → flight abandoned,
            // followers wake and compute for themselves.
            results[i] = Some(res);
        }

        // Resolve hits, intra-batch duplicates, and cross-worker follows
        // (dup targets always precede their copies, so `results[j]` is
        // resolved by the time `Dup(j)` is reached).
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Slot::Exec => {}
                Slot::Ready(p) => results[i] = Some(Ok(p)),
                Slot::Dup(j) => {
                    results[i] = Some(match &results[j] {
                        Some(Ok(p)) => Ok(p.clone()),
                        _ => Err(anyhow::anyhow!(
                            "coalesced batch member's leader failed"
                        )),
                    });
                }
                Slot::Follow(w) => {
                    let fetched = w
                        .wait(Self::FLIGHT_WAIT)
                        .and_then(|bytes| Payload::decode(&bytes).ok());
                    results[i] = Some(match fetched {
                        Some(p) => Ok(p),
                        None => {
                            // Leader failed / timed out: compute it
                            // ourselves — coalescing must never turn
                            // into a correctness dependency.
                            shared.util.busy();
                            let uid = members[i].header.uid;
                            shared.trace(
                                uid,
                                Some(role.stage_index),
                                crate::trace::EventKind::ExecBegin,
                            );
                            let r = logic.execute(&role.stage_name, exec, &members[i]);
                            shared.trace(
                                uid,
                                Some(role.stage_index),
                                crate::trace::EventKind::ExecEnd,
                            );
                            shared.util.idle_n(1);
                            r
                        }
                    });
                }
            }
        }
        // Every slot is filled by the loop above; if a coalescing bug
        // ever leaves one unresolved, fail that member through the
        // normal error path (strand + replay budget) instead of tearing
        // the worker down mid-batch.
        results
            .into_iter()
            .map(|r| {
                r.unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "batch member left unresolved by execute_batch"
                    ))
                })
            })
            .collect()
    }

    /// The instance's inbox ring region (senders route here).
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// Node id.
    pub fn node(&self) -> NodeId {
        self.shared.node
    }

    /// Windowed utilization (what the TaskManager reports to the NM).
    pub fn utilization(&self) -> f64 {
        self.shared.util.value()
    }

    /// Crash injection: simulate this instance dying. All threads go
    /// dormant (no heartbeats, no ring drains, no stage work); the NM's
    /// failure detector notices the missing utilization reports and the
    /// recovery sweep repairs routing and replays stranded requests.
    pub fn inject_crash(&self) {
        self.shared.crashed.store(true, Ordering::SeqCst);
    }

    /// True once [`Instance::inject_crash`] (or a [`CrashHandle`]) fired.
    pub fn is_crashed(&self) -> bool {
        self.shared.crashed.load(Ordering::SeqCst)
    }

    /// Remote-control switch for the set's chaos driver.
    pub fn crash_handle(&self) -> CrashHandle {
        CrashHandle { crashed: self.shared.crashed.clone() }
    }

    /// Stats snapshot.
    pub fn stats(&self) -> InstanceStats {
        let (delivered, dropped) = self.shared.deliver.lock().unwrap().counts();
        InstanceStats {
            processed: self.shared.processed.load(Ordering::Relaxed),
            delivered,
            dropped,
            errors: self.shared.errors.load(Ordering::Relaxed),
            sla_dropped: self.shared.sla_dropped.load(Ordering::Relaxed),
        }
    }

    /// Stop all threads and join.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Priority;
    use crate::metrics::Registry;
    use crate::transport::{AppId, MessageHeader, Payload};
    use crate::util::{SystemClock, Uid};
    use crate::workflow::{EchoLogic, NextHop};

    /// Static control plane for tests.
    struct FixedControl(Assignment);

    impl ControlPlane for FixedControl {
        fn get_assignment(&self, _node: NodeId) -> Assignment {
            self.0.clone()
        }
        fn report_utilization(&self, _node: NodeId, _util: f64) {}
    }

    fn mk_msg(i: u32, stage: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(1),
                stage: StageId(stage),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8; 8]),
        }
    }

    fn mk_tracker(clock: &Arc<dyn Clock>) -> Arc<RequestTracker> {
        Arc::new(RequestTracker::new(clock.clone(), Registry::new()))
    }

    fn echo_assignment() -> Assignment {
        Assignment {
            version: 1,
            role: Some(StageRole {
                app: AppId(1),
                stage_index: 0,
                stage_name: "echo".into(),
                mode: SchedMode::Individual,
                workers: 2,
                routes: vec![(AppId(1), vec![NextHop::Database])],
                batch: None,
            }),
        }
    }

    #[test]
    fn instance_processes_and_stores() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::from_micros(50) });

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(1), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            mk_tracker(&clock),
            clock,
        );

        // Wait for the control thread to apply the assignment, then feed
        // requests through the ring.
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        for i in 0..5 {
            assert!(tx.send(&mk_msg(i, 0)));
        }

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while db.len() < 5 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(db.len(), 5, "all results stored");
        // Delivered messages carry the advanced stage id.
        let stored = db.fetch(Uid(0)).unwrap();
        let m = WorkflowMessage::decode(&stored).unwrap();
        assert_eq!(m.header.stage, StageId(1));
        let stats = inst.stats();
        assert_eq!(stats.processed, 5);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.sla_dropped, 0);
        inst.shutdown();
    }

    #[test]
    fn batched_assignment_coalesces_and_counts_per_request() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::from_millis(3) });
        let tracker = mk_tracker(&clock);
        let mut assignment = echo_assignment();
        if let Some(role) = assignment.role.as_mut() {
            role.batch = Some(crate::batch::BatchPolicy::from_settings(
                &crate::config::BatchSettings {
                    max_batch: 4,
                    max_wait_us: 50_000,
                    adaptive: false,
                    interactive_bypass: true,
                    max_starvation_ms: 0,
                },
            ));
        }
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(9), max_workers: 2, ..Default::default() },
            &fabric,
            Arc::new(FixedControl(assignment)),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            tracker.clone(),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        for i in 0..8 {
            // Batch-class requests coalesce (Interactive would bypass).
            tracker.register(Uid(i as u128), Priority::Batch, None);
            assert!(tx.send(&mk_msg(i, 0)));
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while db.len() < 8 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(db.len(), 8, "every member's result is stored");
        let stats = inst.stats();
        assert_eq!(stats.processed, 8, "processed counts per request, not per batch");
        assert_eq!(stats.errors, 0);
        let m = tracker.metrics();
        assert!(m.counter("batches_executed").get() >= 1, "batches formed");
        assert!(
            m.histogram("batch_size").snapshot().max >= 2,
            "at least one multi-member batch (worker 1 coalesces; worker 0 is the \
             fast lane)"
        );
        inst.shutdown();
    }

    #[test]
    fn batching_stage_reports_its_window_to_the_control_plane() {
        // A static-window policy must export its configured cap — the
        // NM reads 0 as "not batching" and would misjudge the stage.
        struct Capture(Assignment, Arc<AtomicU64>);
        impl ControlPlane for Capture {
            fn get_assignment(&self, _node: NodeId) -> Assignment {
                self.0.clone()
            }
            fn report_utilization(&self, _node: NodeId, _util: f64) {}
            fn report_batch_window(&self, _node: NodeId, window_us: u64) {
                self.1.store(window_us, Ordering::SeqCst);
            }
        }
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let mut assignment = echo_assignment();
        if let Some(role) = assignment.role.as_mut() {
            role.batch = Some(crate::batch::BatchPolicy::from_settings(
                &crate::config::BatchSettings {
                    max_batch: 8,
                    max_wait_us: 2_000,
                    adaptive: false,
                    interactive_bypass: true,
                    max_starvation_ms: 0,
                },
            ));
        }
        let seen = Arc::new(AtomicU64::new(u64::MAX));
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(11), ..Default::default() },
            &fabric,
            Arc::new(Capture(assignment, seen.clone())),
            Arc::new(EchoLogic),
            pool,
            vec![],
            mk_tracker(&clock),
            clock,
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while seen.load(Ordering::SeqCst) == u64::MAX
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            seen.load(Ordering::SeqCst),
            2_000,
            "static-window stages report their cap, never 0"
        );
        inst.shutdown();
    }

    #[test]
    fn cache_enabled_instance_executes_identical_inputs_once() {
        use crate::config::CacheSettings;
        /// Echo that counts stage executions (the thing a cache hit must
        /// skip).
        struct CountingEcho(Arc<AtomicU64>);
        impl AppLogic for CountingEcho {
            fn execute(
                &self,
                _s: &str,
                exec: &StageExecutor,
                msg: &WorkflowMessage,
            ) -> anyhow::Result<Payload> {
                self.0.fetch_add(1, Ordering::SeqCst);
                exec.run(&[])?;
                Ok(msg.payload.clone())
            }
        }
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let tracker = mk_tracker(&clock);
        let reg = tracker.metrics().clone();
        let cache = Arc::new(crate::cache::ArtifactCache::new(
            fabric.clone(),
            clock.clone(),
            &CacheSettings::default(),
            &reg,
        ));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::from_micros(200) });
        let executions = Arc::new(AtomicU64::new(0));
        let inst = Instance::spawn(
            InstanceConfig {
                node: NodeId(6),
                cache: Some(cache),
                ..Default::default()
            },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(CountingEcho(executions.clone())),
            pool,
            vec![db.clone()],
            tracker,
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        let send = |tx: &mut crate::transport::RdmaSender, uid: u32| {
            let mut m = mk_msg(uid, 0);
            m.payload = Payload::Bytes(b"same prompt".to_vec()); // identical input
            assert!(tx.send(&m));
        };
        // First request misses and executes; wait for its result so the
        // fill definitely lands before the repeats arrive.
        send(&mut tx, 1);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while db.len() < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        send(&mut tx, 2);
        send(&mut tx, 3);
        while db.len() < 3 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(db.len(), 3, "every request still gets its own result");
        assert_eq!(
            executions.load(Ordering::SeqCst),
            1,
            "repeat inputs are served from the cache, not recomputed"
        );
        assert_eq!(reg.counter("cache_hits.echo").get(), 2);
        assert_eq!(reg.counter("cache_misses.echo").get(), 1);
        // Each hit's stored result is byte-identical in payload but keeps
        // its own uid (headers are per-request, outside the cached bytes).
        let a = WorkflowMessage::decode(&db.fetch(Uid(1)).unwrap()).unwrap();
        let b = WorkflowMessage::decode(&db.fetch(Uid(2)).unwrap()).unwrap();
        assert_eq!(a.payload, b.payload);
        assert_eq!(b.header.uid, Uid(2));
        inst.shutdown();
    }

    #[test]
    fn idle_instance_ignores_traffic() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(2), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(Assignment { version: 1, role: None })),
            Arc::new(EchoLogic),
            ExecutorPool::new(),
            vec![],
            mk_tracker(&clock),
            clock,
        );
        std::thread::sleep(Duration::from_millis(30));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        tx.send(&mk_msg(1, 0));
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(inst.stats().processed, 0);
        inst.shutdown();
    }

    #[test]
    fn crashed_instance_goes_dormant_but_shuts_down() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(4), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            mk_tracker(&clock),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        assert!(tx.send(&mk_msg(1, 0)));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while inst.stats().processed < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(inst.stats().processed, 1);

        let handle = inst.crash_handle();
        handle.kill();
        assert!(handle.is_crashed() && inst.is_crashed());
        // Messages after the crash strand in the ring: no processing, no
        // stores — exactly a dead process, but still joinable.
        assert!(tx.send(&mk_msg(2, 0)));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(inst.stats().processed, 1, "crashed instance does no work");
        assert_eq!(db.len(), 1);
        inst.shutdown();
    }

    #[test]
    fn cancelled_request_is_dropped_with_tombstone() {
        let fabric = Fabric::ideal();
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        let db = Arc::new(MemDb::new(clock.clone(), u64::MAX));
        let mut pool = ExecutorPool::new();
        pool.insert("echo", StageExecutor::Simulated { busy: Duration::ZERO });
        let tracker = mk_tracker(&clock);

        let inst = Instance::spawn(
            InstanceConfig { node: NodeId(3), ..Default::default() },
            &fabric,
            Arc::new(FixedControl(echo_assignment())),
            Arc::new(EchoLogic),
            pool,
            vec![db.clone()],
            tracker.clone(),
            clock,
        );
        std::thread::sleep(Duration::from_millis(50));

        // Register + cancel BEFORE the message arrives: the RS drop path.
        let m = mk_msg(9, 0);
        tracker.register(m.header.uid, Priority::Standard, None);
        tracker.cancel(m.header.uid);
        let mut tx = crate::transport::RdmaEndpoint::sender_for(&fabric, inst.region_id()).unwrap();
        assert!(tx.send(&m));

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while inst.stats().sla_dropped < 1 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(inst.stats().sla_dropped, 1);
        assert_eq!(inst.stats().processed, 0, "no compute spent on cancelled work");
        assert_eq!(
            db.fetch_entry(m.header.uid),
            Some((EntryKind::Cancelled, vec![])),
            "tombstone published instead of a result"
        );
        // The entry stays so late copies (CM ranks, delayed ring writes)
        // keep dropping; the handle or the housekeeper sweep removes it.
        assert_eq!(
            tracker.verdict(m.header.uid),
            InFlightVerdict::Cancelled,
            "late copies of a dropped request must still drop"
        );
        inst.shutdown();
    }
}
