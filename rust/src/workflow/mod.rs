//! Workflow instances (§4): the runtime entity executing one stage of an
//! AIGC workflow. Each instance has the paper's four components:
//!
//! - **TaskManager** — syncs its assignment (models, routing, mode) with
//!   the NodeManager, initializes executors, reports GPU utilization
//!   (§4.2). Here: a control thread polling a [`ControlPlane`].
//! - **RequestScheduler** — receives requests written into its ring
//!   buffer via one-sided RDMA and dispatches them to workers in
//!   Individual Mode (shared pull queue) or Collaboration Mode
//!   (broadcast) (§4.3, Figure 4).
//! - **TaskWorkers** — execute the user-provided application logic
//!   against the stage's executor (§4.4).
//! - **ResultDeliver** — forwards outputs to the next stage's instances
//!   round-robin, or to the database layer for the final stage (§4.5).

mod deliver;
mod instance;
mod logic;
mod scheduler;

pub use deliver::{Delivery, NextHop, ResultDeliver};
pub use instance::{CrashHandle, Instance, InstanceConfig, InstanceStats};
pub use logic::{AppLogic, EchoLogic, I2vLogic, I2V_BATCH_FIXED_FRAC};
pub use scheduler::{RequestScheduler, SchedQueue};

use crate::config::SchedMode;
use crate::transport::AppId;
use crate::util::NodeId;

/// What the NodeManager wants an instance to run (§8.2 "State Delivery").
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// Monotone version; a bump triggers instance reconfiguration.
    pub version: u64,
    /// `None` = idle (parked in the idle pool, §8.2).
    pub role: Option<StageRole>,
}

/// An assigned stage role. `routes` is keyed by app id because an
/// instance may be shared across workflows (§8.3) whose next stages
/// differ.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRole {
    /// Primary app (the stage's owner; shared apps appear in `routes`).
    pub app: AppId,
    pub stage_index: u32,
    pub stage_name: String,
    pub mode: SchedMode,
    pub workers: usize,
    /// Per-app delivery destinations.
    pub routes: Vec<(AppId, Vec<NextHop>)>,
    /// Micro-batching policy for this stage (None = the single-request
    /// path; resolved by the NM from the config's `batch` blocks —
    /// Individual Mode only).
    pub batch: Option<crate::batch::BatchPolicy>,
}

/// The instance-facing slice of the NodeManager. Implemented by
/// [`crate::nm::NodeManager`]; trait-shaped so workflow code is testable
/// without a full NM.
pub trait ControlPlane: Send + Sync {
    /// Current assignment for `node` (TaskManager poll).
    fn get_assignment(&self, node: NodeId) -> Assignment;
    /// Periodic utilization report (drives §8.2 rebalancing).
    fn report_utilization(&self, node: NodeId, util: f64);
    /// Periodic batch-window report from batching stages (µs): the
    /// current effective window of the instance's
    /// [`crate::batch::AdaptiveWindow`], piggybacked on the utilization
    /// heartbeat so the §8.2 allocator can tell a stage that is slow
    /// from one that is coalescing on purpose. Default no-op (control
    /// planes without elastic scaling can ignore it).
    fn report_batch_window(&self, _node: NodeId, _window_us: u64) {}
}
