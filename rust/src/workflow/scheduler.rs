//! RequestScheduler (§4.3): dispatches ring-buffer arrivals to workers.
//!
//! Individual Mode uses a *pull* queue — "instead of pushing requests
//! directly to workers, which could cause load imbalance, the RS
//! maintains a shared local request queue; idle workers autonomously
//! fetch tasks" (Figure 4a). Collaboration Mode broadcasts each request
//! to every worker (Figure 4b).
//!
//! The shared IM queue is **priority-banded** for the SLO tiers of the
//! unified [`crate::client`] API: Interactive arrivals are fetched ahead
//! of Standard, and Standard ahead of Batch, so a backlog building at a
//! bottleneck stage adds queueing delay to Batch traffic while
//! Interactive latency stays flat. Within a band, order stays FIFO.

use crate::client::Priority;
use crate::config::SchedMode;
use crate::transport::WorkflowMessage;
use crate::util::Uid;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared scheduling queue between the RS thread and the worker pool.
pub struct SchedQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

struct Inner {
    mode: SchedMode,
    workers: usize,
    /// IM: one FIFO per priority band, drained highest-priority-first.
    bands: [VecDeque<WorkflowMessage>; 3],
    /// CM: one broadcast copy per worker.
    per_worker: Vec<VecDeque<WorkflowMessage>>,
    closed: bool,
    generation: u64,
}

impl SchedQueue {
    pub fn new(mode: SchedMode, workers: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(Inner {
                mode,
                workers: workers.max(1),
                bands: Default::default(),
                per_worker: vec![VecDeque::new(); workers.max(1)],
                closed: false,
                generation: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Reconfigure mode/worker-count (assignment change). A route-only
    /// update (same mode, same worker count) preserves pending work;
    /// a real mode/shape change drains it and **returns** the displaced
    /// messages (CM broadcast copies deduplicated by UID) so the caller
    /// can strand them for recovery instead of losing them silently.
    pub fn reconfigure(&self, mode: SchedMode, workers: usize) -> Vec<WorkflowMessage> {
        let workers = workers.max(1);
        let mut g = self.inner.lock().unwrap();
        if g.mode == mode && g.workers == workers {
            return Vec::new(); // pending work is still valid
        }
        let dropped = Self::drain_locked(&mut g);
        g.mode = mode;
        g.workers = workers;
        g.per_worker = vec![VecDeque::new(); g.workers];
        g.generation += 1;
        drop(g);
        self.cv.notify_all();
        dropped
    }

    /// Drain everything pending (deduplicating CM broadcast copies by
    /// UID) — used when the instance parks to idle, so displaced work
    /// reaches the recovery path exactly once per request.
    pub fn drain_pending(&self) -> Vec<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        Self::drain_locked(&mut g)
    }

    /// Current scheduling mode (workers consult this while roleless).
    pub fn mode(&self) -> SchedMode {
        self.inner.lock().unwrap().mode
    }

    fn drain_locked(g: &mut Inner) -> Vec<WorkflowMessage> {
        let mut out: Vec<WorkflowMessage> = Vec::new();
        for band in g.bands.iter_mut() {
            out.extend(band.drain(..));
        }
        let mut seen: std::collections::HashSet<Uid> =
            out.iter().map(|m| m.header.uid).collect();
        for q in g.per_worker.iter_mut() {
            for m in q.drain(..) {
                if seen.insert(m.header.uid) {
                    out.push(m);
                }
            }
        }
        out
    }

    /// RS side: enqueue one arrival per the active mode, into its
    /// priority band (IM) or broadcast to every worker (CM — collective
    /// execution cannot reorder per-rank).
    pub fn dispatch(&self, msg: WorkflowMessage, priority: Priority) {
        let mut g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => g.bands[priority.index()].push_back(msg),
            SchedMode::Collaboration => {
                for q in g.per_worker.iter_mut() {
                    q.push_back(msg.clone());
                }
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Worker side: blocking fetch with timeout. In IM any worker takes
    /// the highest-priority pending message (pull = natural load
    /// balancing; bands = SLO ordering); in CM worker `widx` takes its
    /// broadcast copy.
    pub fn fetch(&self, widx: usize, timeout: Duration) -> Option<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if g.closed {
                return None;
            }
            let got = match g.mode {
                SchedMode::Individual => {
                    g.bands.iter_mut().find_map(VecDeque::pop_front)
                }
                SchedMode::Collaboration => {
                    g.per_worker.get_mut(widx).and_then(|q| q.pop_front())
                }
            };
            if let Some(m) = got {
                return Some(m);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Pending depth (IM: all bands; CM: max per-worker).
    pub fn depth(&self) -> usize {
        let g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => g.bands.iter().map(VecDeque::len).sum(),
            SchedMode::Collaboration => {
                g.per_worker.iter().map(VecDeque::len).max().unwrap_or(0)
            }
        }
    }

    /// Wake and permanently release all workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Thin RS façade: couples an arrival source to a [`SchedQueue`] (the
/// instance's RS thread calls `on_arrival` for each ring-buffer message).
pub struct RequestScheduler {
    queue: Arc<SchedQueue>,
}

impl RequestScheduler {
    pub fn new(queue: Arc<SchedQueue>) -> Self {
        Self { queue }
    }

    /// Handle one arrival.
    pub fn on_arrival(&self, msg: WorkflowMessage, priority: Priority) {
        self.queue.dispatch(msg, priority);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(0),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8]),
        }
    }

    #[test]
    fn im_single_delivery() {
        let q = SchedQueue::new(SchedMode::Individual, 2);
        q.dispatch(msg(1), Priority::Standard);
        let a = q.fetch(0, Duration::from_millis(10));
        let b = q.fetch(1, Duration::from_millis(10));
        // Exactly one worker gets it.
        assert_eq!(a.is_some() as u32 + b.is_some() as u32, 1);
    }

    #[test]
    fn cm_broadcast_delivery() {
        let q = SchedQueue::new(SchedMode::Collaboration, 3);
        q.dispatch(msg(7), Priority::Standard);
        for w in 0..3 {
            assert_eq!(
                q.fetch(w, Duration::from_millis(10)).unwrap().header.uid.0,
                7
            );
        }
    }

    #[test]
    fn im_pull_balances() {
        // 4 messages, 2 workers: each pulls what it can — no worker can
        // be overloaded while the other idles.
        let q = SchedQueue::new(SchedMode::Individual, 2);
        for i in 0..4 {
            q.dispatch(msg(i), Priority::Standard);
        }
        let mut counts = [0usize; 2];
        for _ in 0..4 {
            for (w, c) in counts.iter_mut().enumerate() {
                if q.fetch(w, Duration::from_millis(1)).is_some() {
                    *c += 1;
                }
            }
        }
        assert_eq!(counts[0] + counts[1], 4);
        assert!(counts[0] >= 1 && counts[1] >= 1);
    }

    #[test]
    fn interactive_jumps_the_queue() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1), Priority::Batch);
        q.dispatch(msg(2), Priority::Standard);
        q.dispatch(msg(3), Priority::Interactive);
        q.dispatch(msg(4), Priority::Interactive);
        let order: Vec<u128> = (0..4)
            .map(|_| q.fetch(0, Duration::from_millis(10)).unwrap().header.uid.0)
            .collect();
        // Interactive first (FIFO within the band), then Standard, then
        // Batch.
        assert_eq!(order, vec![3, 4, 2, 1]);
    }

    #[test]
    fn fetch_times_out() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let t0 = std::time::Instant::now();
        assert!(q.fetch(0, Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn reconfigure_switches_mode() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1), Priority::Standard);
        let displaced = q.reconfigure(SchedMode::Collaboration, 2);
        assert_eq!(q.depth(), 0, "reconfigure drains pending work");
        assert_eq!(displaced.len(), 1, "displaced work is returned, not lost");
        q.dispatch(msg(2), Priority::Standard);
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
        assert!(q.fetch(1, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn route_only_reconfigure_preserves_pending() {
        let q = SchedQueue::new(SchedMode::Individual, 2);
        q.dispatch(msg(1), Priority::Standard);
        // Same mode + worker count (a routing-only assignment bump):
        // pending work must survive.
        assert!(q.reconfigure(SchedMode::Individual, 2).is_empty());
        assert_eq!(q.depth(), 1);
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn drain_pending_dedupes_cm_broadcast_copies() {
        let q = SchedQueue::new(SchedMode::Collaboration, 3);
        q.dispatch(msg(7), Priority::Standard);
        q.dispatch(msg(8), Priority::Standard);
        let mut uids: Vec<u128> =
            q.drain_pending().iter().map(|m| m.header.uid.0).collect();
        uids.sort();
        assert_eq!(uids, vec![7, 8], "one copy per request, not per worker");
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn close_releases_blocked_workers() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.fetch(0, Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }
}
