//! RequestScheduler (§4.3): dispatches ring-buffer arrivals to workers.
//!
//! Individual Mode uses a *pull* queue — "instead of pushing requests
//! directly to workers, which could cause load imbalance, the RS
//! maintains a shared local request queue; idle workers autonomously
//! fetch tasks" (Figure 4a). Collaboration Mode broadcasts each request
//! to every worker (Figure 4b).

use crate::config::SchedMode;
use crate::transport::WorkflowMessage;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared scheduling queue between the RS thread and the worker pool.
pub struct SchedQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

struct Inner {
    mode: SchedMode,
    workers: usize,
    /// IM: single shared queue.
    shared: VecDeque<WorkflowMessage>,
    /// CM: one broadcast copy per worker.
    per_worker: Vec<VecDeque<WorkflowMessage>>,
    closed: bool,
    generation: u64,
}

impl SchedQueue {
    pub fn new(mode: SchedMode, workers: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(Inner {
                mode,
                workers: workers.max(1),
                shared: VecDeque::new(),
                per_worker: vec![VecDeque::new(); workers.max(1)],
                closed: false,
                generation: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Reconfigure mode/worker-count (assignment change). Pending work is
    /// dropped — the paper's no-retransmission stance extends to
    /// reassignment; in-flight requests expire at the client.
    pub fn reconfigure(&self, mode: SchedMode, workers: usize) {
        let mut g = self.inner.lock().unwrap();
        g.mode = mode;
        g.workers = workers.max(1);
        g.shared.clear();
        g.per_worker = vec![VecDeque::new(); g.workers];
        g.generation += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// RS side: enqueue one arrival per the active mode.
    pub fn dispatch(&self, msg: WorkflowMessage) {
        let mut g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => g.shared.push_back(msg),
            SchedMode::Collaboration => {
                for q in g.per_worker.iter_mut() {
                    q.push_back(msg.clone());
                }
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// Worker side: blocking fetch with timeout. In IM any worker takes
    /// from the shared queue (pull = natural load balancing); in CM
    /// worker `widx` takes its broadcast copy.
    pub fn fetch(&self, widx: usize, timeout: Duration) -> Option<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if g.closed {
                return None;
            }
            let got = match g.mode {
                SchedMode::Individual => g.shared.pop_front(),
                SchedMode::Collaboration => {
                    g.per_worker.get_mut(widx).and_then(|q| q.pop_front())
                }
            };
            if let Some(m) = got {
                return Some(m);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Pending depth (IM: shared queue; CM: max per-worker).
    pub fn depth(&self) -> usize {
        let g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => g.shared.len(),
            SchedMode::Collaboration => {
                g.per_worker.iter().map(VecDeque::len).max().unwrap_or(0)
            }
        }
    }

    /// Wake and permanently release all workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Thin RS façade: couples an arrival source to a [`SchedQueue`] (the
/// instance's RS thread calls `on_arrival` for each ring-buffer message).
pub struct RequestScheduler {
    queue: Arc<SchedQueue>,
}

impl RequestScheduler {
    pub fn new(queue: Arc<SchedQueue>) -> Self {
        Self { queue }
    }

    /// Handle one arrival.
    pub fn on_arrival(&self, msg: WorkflowMessage) {
        self.queue.dispatch(msg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(0),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8]),
        }
    }

    #[test]
    fn im_single_delivery() {
        let q = SchedQueue::new(SchedMode::Individual, 2);
        q.dispatch(msg(1));
        let a = q.fetch(0, Duration::from_millis(10));
        let b = q.fetch(1, Duration::from_millis(10));
        // Exactly one worker gets it.
        assert_eq!(a.is_some() as u32 + b.is_some() as u32, 1);
    }

    #[test]
    fn cm_broadcast_delivery() {
        let q = SchedQueue::new(SchedMode::Collaboration, 3);
        q.dispatch(msg(7));
        for w in 0..3 {
            assert_eq!(
                q.fetch(w, Duration::from_millis(10)).unwrap().header.uid.0,
                7
            );
        }
    }

    #[test]
    fn im_pull_balances() {
        // 4 messages, 2 workers: each pulls what it can — no worker can
        // be overloaded while the other idles.
        let q = SchedQueue::new(SchedMode::Individual, 2);
        for i in 0..4 {
            q.dispatch(msg(i));
        }
        let mut counts = [0usize; 2];
        for _ in 0..4 {
            for (w, c) in counts.iter_mut().enumerate() {
                if q.fetch(w, Duration::from_millis(1)).is_some() {
                    *c += 1;
                }
            }
        }
        assert_eq!(counts[0] + counts[1], 4);
        assert!(counts[0] >= 1 && counts[1] >= 1);
    }

    #[test]
    fn fetch_times_out() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let t0 = std::time::Instant::now();
        assert!(q.fetch(0, Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn reconfigure_switches_mode() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1));
        q.reconfigure(SchedMode::Collaboration, 2);
        assert_eq!(q.depth(), 0, "reconfigure drops pending work");
        q.dispatch(msg(2));
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
        assert!(q.fetch(1, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn close_releases_blocked_workers() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.fetch(0, Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }
}
