//! RequestScheduler (§4.3): dispatches ring-buffer arrivals to workers.
//!
//! Individual Mode uses a *pull* queue — "instead of pushing requests
//! directly to workers, which could cause load imbalance, the RS
//! maintains a shared local request queue; idle workers autonomously
//! fetch tasks" (Figure 4a). Collaboration Mode broadcasts each request
//! to every worker (Figure 4b).
//!
//! The shared IM queue is **priority-banded** for the SLO tiers of the
//! unified [`crate::client`] API: Interactive arrivals are fetched ahead
//! of Standard, and Standard ahead of Batch, so a backlog building at a
//! bottleneck stage adds queueing delay to Batch traffic while
//! Interactive latency stays flat. Within a band, order stays FIFO.

use crate::client::Priority;
use crate::config::SchedMode;
use crate::lint::runtime::{WitnessMutex, RANK_SCHEDULER};
use crate::transport::{AppId, StageId, WorkflowMessage};
use crate::util::Uid;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

/// Shared scheduling queue between the RS thread and the worker pool.
pub struct SchedQueue {
    inner: WitnessMutex<Inner>, // lint: lock-rank(scheduler, 45)
    cv: Condvar,
}

struct Inner {
    mode: SchedMode,
    workers: usize,
    /// IM: one FIFO per priority band (message + enqueue time, for the
    /// aging guard), drained highest-priority-first.
    bands: [VecDeque<(WorkflowMessage, Instant)>; 3],
    /// CM: one broadcast copy per worker.
    per_worker: Vec<VecDeque<WorkflowMessage>>,
    /// Aging guard against band starvation: a queued message older than
    /// this is promoted past higher bands. `None` = strict
    /// highest-band-first (the default).
    max_starvation: Option<Duration>,
    closed: bool,
    generation: u64,
}

impl SchedQueue {
    pub fn new(mode: SchedMode, workers: usize) -> Arc<Self> {
        Self::with_aging(mode, workers, Duration::ZERO)
    }

    /// Like [`SchedQueue::new`] but with the starvation guard enabled:
    /// strict highest-band-first draining can starve the Batch band
    /// indefinitely under sustained Interactive load, so a message
    /// queued longer than `max_starvation` (> 0) is promoted ahead of
    /// younger higher-band arrivals. `Duration::ZERO` keeps the guard
    /// off.
    pub fn with_aging(
        mode: SchedMode,
        workers: usize,
        max_starvation: Duration,
    ) -> Arc<Self> {
        Arc::new(Self {
            inner: WitnessMutex::new("scheduler", RANK_SCHEDULER, Inner {
                mode,
                workers: workers.max(1),
                bands: Default::default(),
                per_worker: vec![VecDeque::new(); workers.max(1)],
                max_starvation: (!max_starvation.is_zero()).then_some(max_starvation),
                closed: false,
                generation: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Reconfigure mode/worker-count (assignment change). A route-only
    /// update (same mode, same worker count) preserves pending work;
    /// a real mode/shape change drains it and **returns** the displaced
    /// messages (CM broadcast copies deduplicated by UID) so the caller
    /// can strand them for recovery instead of losing them silently.
    pub fn reconfigure(&self, mode: SchedMode, workers: usize) -> Vec<WorkflowMessage> {
        let workers = workers.max(1);
        let mut g = self.inner.lock().unwrap();
        if g.mode == mode && g.workers == workers {
            return Vec::new(); // pending work is still valid
        }
        let dropped = Self::drain_locked(&mut g);
        g.mode = mode;
        g.workers = workers;
        g.per_worker = vec![VecDeque::new(); g.workers];
        g.generation += 1;
        drop(g);
        self.cv.notify_all();
        dropped
    }

    /// Drain everything pending (deduplicating CM broadcast copies by
    /// UID) — used when the instance parks to idle, so displaced work
    /// reaches the recovery path exactly once per request.
    pub fn drain_pending(&self) -> Vec<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        Self::drain_locked(&mut g)
    }

    /// Current scheduling mode (workers consult this while roleless).
    pub fn mode(&self) -> SchedMode {
        self.inner.lock().unwrap().mode
    }

    fn drain_locked(g: &mut Inner) -> Vec<WorkflowMessage> {
        let mut out: Vec<WorkflowMessage> = Vec::new();
        for band in g.bands.iter_mut() {
            out.extend(band.drain(..).map(|(m, _)| m));
        }
        let mut seen: std::collections::HashSet<Uid> =
            out.iter().map(|m| m.header.uid).collect();
        for q in g.per_worker.iter_mut() {
            for m in q.drain(..) {
                if seen.insert(m.header.uid) {
                    out.push(m);
                }
            }
        }
        out
    }

    /// RS side: enqueue one arrival per the active mode, into its
    /// priority band (IM) or broadcast to every worker (CM — collective
    /// execution cannot reorder per-rank).
    pub fn dispatch(&self, msg: WorkflowMessage, priority: Priority) {
        let mut g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => {
                g.bands[priority.index()].push_back((msg, Instant::now()))
            }
            SchedMode::Collaboration => {
                for q in g.per_worker.iter_mut() {
                    q.push_back(msg.clone());
                }
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    /// IM pop restricted to `allowed` bands: the aging guard first (the
    /// *oldest* starved message in an allowed lower band jumps ahead —
    /// Interactive, band 0, can never starve by construction), then
    /// strict highest-band-first.
    fn pop_im(g: &mut Inner, allowed: &[bool; 3]) -> Option<WorkflowMessage> {
        if let Some(max_age) = g.max_starvation {
            let now = Instant::now();
            let mut starved: Option<(usize, Instant)> = None;
            for (b, q) in g.bands.iter().enumerate().skip(1) {
                if !allowed[b] {
                    continue;
                }
                if let Some((_, ts)) = q.front() {
                    if now.duration_since(*ts) >= max_age
                        && starved.is_none_or(|(_, best)| *ts < best)
                    {
                        starved = Some((b, *ts));
                    }
                }
            }
            if let Some((b, _)) = starved {
                return g.bands[b].pop_front().map(|(m, _)| m);
            }
        }
        g.bands
            .iter_mut()
            .zip(allowed)
            .find_map(|(q, ok)| ok.then(|| q.pop_front().map(|(m, _)| m)).flatten())
    }

    /// Worker side: blocking fetch with timeout. In IM any worker takes
    /// the highest-priority pending message (pull = natural load
    /// balancing; bands = SLO ordering; the aging guard promotes starved
    /// lower-band messages); in CM worker `widx` takes its broadcast
    /// copy.
    pub fn fetch(&self, widx: usize, timeout: Duration) -> Option<WorkflowMessage> {
        self.fetch_from(widx, [true; 3], timeout)
    }

    /// [`SchedQueue::fetch`] restricted to a subset of priority bands
    /// (IM only; the mask is ignored in CM, where every rank must
    /// consume its broadcast copy). The reserved fast lane of a batching
    /// stage uses this to serve *only* the bypass classes, so a
    /// bypassing Interactive arrival never waits behind a worker pool
    /// that is entirely mid-batch.
    pub fn fetch_from(
        &self,
        widx: usize,
        allowed: [bool; 3],
        timeout: Duration,
    ) -> Option<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        let deadline = Instant::now() + timeout;
        loop {
            if g.closed {
                return None;
            }
            let got = match g.mode {
                SchedMode::Individual => Self::pop_im(&mut g, &allowed),
                SchedMode::Collaboration => {
                    g.per_worker.get_mut(widx).and_then(|q| q.pop_front())
                }
            };
            if let Some(m) = got {
                return Some(m);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = g.wait_timeout(&self.cv, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Batch-assembly fetch: block until a *compatible* message — same
    /// app, same stage, in priority band `band` — is available, or
    /// `deadline` passes. Incompatible messages are left queued (in
    /// order) for other workers; Individual Mode only (`None`
    /// immediately if the queue is reconfigured into CM mid-wait, so an
    /// assembling worker never holds a broadcast copy hostage).
    pub fn fetch_matching(
        &self,
        band: usize,
        app: AppId,
        stage: StageId,
        deadline: Instant,
    ) -> Option<WorkflowMessage> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed || g.mode != SchedMode::Individual {
                return None;
            }
            let found = g.bands.get_mut(band).and_then(|q| {
                q.iter()
                    .position(|(m, _)| m.header.app == app && m.header.stage == stage)
                    .and_then(|idx| q.remove(idx).map(|(m, _)| m))
            });
            if let Some(m) = found {
                return Some(m);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = g.wait_timeout(&self.cv, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Pending messages *compatible* with a forming batch — same app,
    /// same stage, in `band`. The adaptive window controller reads this
    /// (not the whole-queue [`SchedQueue::depth`]) as its backlog
    /// signal: unrelated or bypass-class backlog must not force the
    /// window open for a class that has nothing to coalesce with.
    pub fn depth_matching(&self, band: usize, app: AppId, stage: StageId) -> usize {
        let g = self.inner.lock().unwrap();
        if g.mode != SchedMode::Individual {
            return 0;
        }
        g.bands.get(band).map_or(0, |q| {
            q.iter()
                .filter(|(m, _)| m.header.app == app && m.header.stage == stage)
                .count()
        })
    }

    /// Pending depth (IM: all bands; CM: max per-worker).
    pub fn depth(&self) -> usize {
        let g = self.inner.lock().unwrap();
        match g.mode {
            SchedMode::Individual => g.bands.iter().map(VecDeque::len).sum(),
            SchedMode::Collaboration => {
                g.per_worker.iter().map(VecDeque::len).max().unwrap_or(0)
            }
        }
    }

    /// Wake and permanently release all workers.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Thin RS façade: couples an arrival source to a [`SchedQueue`] (the
/// instance's RS thread calls `on_arrival` for each ring-buffer message).
pub struct RequestScheduler {
    queue: Arc<SchedQueue>,
}

impl RequestScheduler {
    pub fn new(queue: Arc<SchedQueue>) -> Self {
        Self { queue }
    }

    /// Handle one arrival.
    pub fn on_arrival(&self, msg: WorkflowMessage, priority: Priority) {
        self.queue.dispatch(msg, priority);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(0),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8]),
        }
    }

    #[test]
    fn im_single_delivery() {
        let q = SchedQueue::new(SchedMode::Individual, 2);
        q.dispatch(msg(1), Priority::Standard);
        let a = q.fetch(0, Duration::from_millis(10));
        let b = q.fetch(1, Duration::from_millis(10));
        // Exactly one worker gets it.
        assert_eq!(a.is_some() as u32 + b.is_some() as u32, 1);
    }

    #[test]
    fn cm_broadcast_delivery() {
        let q = SchedQueue::new(SchedMode::Collaboration, 3);
        q.dispatch(msg(7), Priority::Standard);
        for w in 0..3 {
            assert_eq!(
                q.fetch(w, Duration::from_millis(10)).unwrap().header.uid.0,
                7
            );
        }
    }

    #[test]
    fn im_pull_balances() {
        // 4 messages, 2 workers: each pulls what it can — no worker can
        // be overloaded while the other idles.
        let q = SchedQueue::new(SchedMode::Individual, 2);
        for i in 0..4 {
            q.dispatch(msg(i), Priority::Standard);
        }
        let mut counts = [0usize; 2];
        for _ in 0..4 {
            for (w, c) in counts.iter_mut().enumerate() {
                if q.fetch(w, Duration::from_millis(1)).is_some() {
                    *c += 1;
                }
            }
        }
        assert_eq!(counts[0] + counts[1], 4);
        assert!(counts[0] >= 1 && counts[1] >= 1);
    }

    #[test]
    fn interactive_jumps_the_queue() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1), Priority::Batch);
        q.dispatch(msg(2), Priority::Standard);
        q.dispatch(msg(3), Priority::Interactive);
        q.dispatch(msg(4), Priority::Interactive);
        let order: Vec<u128> = (0..4)
            .map(|_| q.fetch(0, Duration::from_millis(10)).unwrap().header.uid.0)
            .collect();
        // Interactive first (FIFO within the band), then Standard, then
        // Batch.
        assert_eq!(order, vec![3, 4, 2, 1]);
    }

    #[test]
    fn aging_guard_rescues_batch_band_under_sustained_interactive_load() {
        // Strict highest-band-first would never reach the Batch message
        // while Interactive arrivals keep coming; the aging guard must
        // dispatch it once it has waited `max_starvation`.
        let q = SchedQueue::with_aging(
            SchedMode::Individual,
            1,
            Duration::from_millis(30),
        );
        let batch_uid = 999;
        q.dispatch(msg(batch_uid), Priority::Batch);
        let mut batch_served_after = None;
        for round in 0..200u32 {
            // Continuous Interactive arrivals: one lands before every
            // fetch, so the Interactive band is never empty.
            q.dispatch(msg(round), Priority::Interactive);
            let got = q.fetch(0, Duration::from_millis(10)).unwrap();
            if got.header.uid.0 == batch_uid as u128 {
                batch_served_after = Some(round);
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let round = batch_served_after
            .expect("starved Batch message must eventually dispatch");
        assert!(round > 0, "strict priority still holds before the age bound");
    }

    #[test]
    fn aging_off_starves_lower_bands_indefinitely() {
        // The pre-batching default: without the guard, Batch never runs
        // while Interactive arrivals persist (this is the failure mode
        // the guard exists for).
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(999), Priority::Batch);
        std::thread::sleep(Duration::from_millis(40));
        for i in 0..20 {
            q.dispatch(msg(i), Priority::Interactive);
            let got = q.fetch(0, Duration::from_millis(10)).unwrap();
            assert_eq!(got.header.uid.0, i as u128, "strict band order holds");
        }
        assert_eq!(q.depth(), 1, "the Batch message is still waiting");
    }

    #[test]
    fn fetch_matching_takes_only_compatible_and_preserves_order() {
        use crate::transport::{AppId, StageId};
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let mut other_app = msg(1);
        other_app.header.app = AppId(2);
        q.dispatch(other_app, Priority::Standard);
        q.dispatch(msg(2), Priority::Standard);
        q.dispatch(msg(3), Priority::Standard);
        let deadline = std::time::Instant::now() + Duration::from_millis(20);
        let a = q
            .fetch_matching(Priority::Standard.index(), AppId(0), StageId(0), deadline)
            .unwrap();
        assert_eq!(a.header.uid.0, 2, "skips the incompatible head");
        let b = q
            .fetch_matching(Priority::Standard.index(), AppId(0), StageId(0), deadline)
            .unwrap();
        assert_eq!(b.header.uid.0, 3);
        // Nothing compatible left: blocks until the deadline, then None.
        let t0 = std::time::Instant::now();
        assert!(q
            .fetch_matching(Priority::Standard.index(), AppId(0), StageId(0), deadline)
            .is_none());
        assert!(t0.elapsed() >= Duration::from_millis(1));
        // The incompatible message is still there for a normal fetch.
        assert_eq!(q.fetch(0, Duration::from_millis(10)).unwrap().header.app, AppId(2));
    }

    #[test]
    fn fetch_from_serves_only_allowed_bands() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1), Priority::Batch);
        q.dispatch(msg(2), Priority::Interactive);
        // An Interactive-only mask (the reserved fast lane) takes the
        // Interactive message, then times out with Batch work pending.
        let mask = [true, false, false];
        assert_eq!(
            q.fetch_from(0, mask, Duration::from_millis(10)).unwrap().header.uid.0,
            2
        );
        assert!(q.fetch_from(0, mask, Duration::from_millis(10)).is_none());
        assert_eq!(q.depth(), 1, "the Batch message stays for the other workers");
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn fetch_matching_refuses_collaboration_mode() {
        use crate::transport::{AppId, StageId};
        let q = SchedQueue::new(SchedMode::Collaboration, 2);
        q.dispatch(msg(1), Priority::Standard);
        let deadline = std::time::Instant::now() + Duration::from_secs(1);
        let t0 = std::time::Instant::now();
        assert!(q.fetch_matching(1, AppId(0), StageId(0), deadline).is_none());
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "CM returns immediately, not at the deadline"
        );
    }

    #[test]
    fn fetch_times_out() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let t0 = std::time::Instant::now();
        assert!(q.fetch(0, Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn reconfigure_switches_mode() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        q.dispatch(msg(1), Priority::Standard);
        let displaced = q.reconfigure(SchedMode::Collaboration, 2);
        assert_eq!(q.depth(), 0, "reconfigure drains pending work");
        assert_eq!(displaced.len(), 1, "displaced work is returned, not lost");
        q.dispatch(msg(2), Priority::Standard);
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
        assert!(q.fetch(1, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn route_only_reconfigure_preserves_pending() {
        let q = SchedQueue::new(SchedMode::Individual, 2);
        q.dispatch(msg(1), Priority::Standard);
        // Same mode + worker count (a routing-only assignment bump):
        // pending work must survive.
        assert!(q.reconfigure(SchedMode::Individual, 2).is_empty());
        assert_eq!(q.depth(), 1);
        assert!(q.fetch(0, Duration::from_millis(10)).is_some());
    }

    #[test]
    fn drain_pending_dedupes_cm_broadcast_copies() {
        let q = SchedQueue::new(SchedMode::Collaboration, 3);
        q.dispatch(msg(7), Priority::Standard);
        q.dispatch(msg(8), Priority::Standard);
        let mut uids: Vec<u128> =
            q.drain_pending().iter().map(|m| m.header.uid.0).collect();
        uids.sort();
        assert_eq!(uids, vec![7, 8], "one copy per request, not per worker");
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn close_releases_blocked_workers() {
        let q = SchedQueue::new(SchedMode::Individual, 1);
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.fetch(0, Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }
}
