//! The paper's deadlock-free multi-producer / single-consumer
//! **double-ring buffer** (§6.1) — the novel data structure contribution.
//!
//! Two rings share one registered memory region:
//!
//! - the **buffer region** holds variable-size message frames
//!   (`[payload_len u32][crc32 u32][payload][pad to 8B]`), and
//! - the **size region** holds one word per message: a *busy bit* (bit 63)
//!   plus the frame length. The busy bit can only be cleared by the
//!   consumer — this is what makes post-failure recovery possible without
//!   any CPU on the receiving side (Theorem 2 of the paper).
//!
//! A fixed header carries a CAS spin-lock (the lock word packs the owner
//! tag with the acquire timestamp used for timeout stealing, so one CAS
//! verb both takes the lock and stamps the lease), the producer tail
//! pointers and the consumer
//! head pointers. Pointers are **virtual** (monotonic u64); physical
//! positions are `v % capacity`, and a frame that would straddle the end
//! of the buffer region is placed at offset 0 instead, with both sides
//! computing the identical skip from `(virtual offset, frame length)` —
//! this implements the paper's wrap formula `P_b ← 0` without ever
//! splitting a frame.
//!
//! Producers contend on the lock (and may steal it after
//! `lock_timeout_ns`, the paper's short-timeout deadlock resolution); the
//! consumer is **wait-free**: `pop` performs a bounded number of reads and
//! one store, and never blocks on producer failures. Delayed writers that
//! lost the lock can corrupt at most the frame they collided on; the CRC32
//! in the frame header detects this and the consumer skips the entry using
//! the size-region metadata — exactly the Case1–Case8 liveness argument of
//! §6.1, each of which is reproduced in `tests/ringbuf_liveness.rs` via
//! the stepped [`ProducerSession`] API.
//!
//! All producer-side accesses go through one-sided RDMA verbs
//! ([`crate::rdma::QueuePair`]); the consumer is co-located with the
//! region (the paper assumes "the queue and the consumer are co-located").
//!
//! The producer hot path is **verb-coalesced** (see DESIGN.md's verb
//! budget): the header snapshot is one vectored read, the two tail
//! advances one doorbell-batched CAS pair, and [`RingProducer::push_many`]
//! amortizes the lock acquisition, header ops, and the frame write over a
//! whole micro-batch — k messages cross the fabric in k+5 verbs instead
//! of 12·k. [`RingConsumer::pop_many`] is the receiving mirror.

mod consumer;
mod producer;
mod single;

pub use consumer::{Frame, PopError, RingConsumer};
pub use producer::{
    BatchPushOutcome, DieAt, ProducerSession, PushError, PushOutcome, RingProducer,
};
pub use single::{SingleRingConsumer, SingleRingProducer, SingleRingPushError};

use crate::rdma::{Fabric, MemoryRegion, RegionId};

/// Header word byte offsets within the region.
pub(crate) mod layout {
    /// CAS spin-lock: 0 = free, else a packed word carrying the owner
    /// tag (high 16 bits) and the acquire timestamp (low 48 bits) — one
    /// CAS both takes the lock and stamps the lease the timeout-steal
    /// inspects (e15 verb coalescing).
    pub const LOCK: usize = 0;
    // Word at byte 8 is reserved (it held the separate lock-timestamp
    // before the timestamp moved into the lock word itself); the region
    // geometry — and every offset below — is unchanged.
    /// Virtual byte offset of the next frame write (producer tail).
    pub const VTAIL_OFF: usize = 16;
    /// Virtual slot index of the next size entry (producer tail).
    pub const VTAIL_SLOT: usize = 24;
    /// Virtual slot index of the next unconsumed entry (consumer head).
    pub const VHEAD_SLOT: usize = 32;
    /// Virtual byte offset of the next unconsumed frame (consumer head).
    pub const VHEAD_OFF: usize = 40;
    /// Ring geometry, written at creation so remote senders can derive
    /// the full [`super::RingConfig`] from the region alone.
    pub const NSLOTS: usize = 48;
    pub const CAP_BYTES: usize = 56;
    /// First byte of the size region.
    pub const SIZE_REGION: usize = 64;

    /// Busy bit in a size word (only the consumer clears it).
    pub const BUSY: u64 = 1 << 63;

    /// Descriptor-frame bit in a size word: the frame body is a
    /// rendezvous [`crate::rdma::PayloadDescriptor`], not an eager
    /// payload. Rides the same WL CAS that publishes the length, so the
    /// kind is exactly as crash-consistent as the busy bit itself; both
    /// bits are masked off wherever a frame length is extracted.
    pub const FRAME_DESC: u64 = 1 << 62;

    /// Mask selecting the frame length from a size word.
    pub const LEN_MASK: u64 = !(BUSY | FRAME_DESC);

    /// Frame header: payload length + CRC32, before the payload bytes.
    pub const FRAME_HDR: usize = 8;
}

/// What a ring frame's bytes are: an eager payload (the message itself)
/// or a rendezvous descriptor pointing at a staged payload region.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FrameKind {
    #[default]
    Eager,
    Descriptor,
}

impl FrameKind {
    /// The size-word bit this kind contributes.
    pub(crate) fn bit(self) -> u64 {
        match self {
            FrameKind::Eager => 0,
            FrameKind::Descriptor => layout::FRAME_DESC,
        }
    }
}

/// Ring buffer geometry and failure-detection tuning.
#[derive(Debug, Clone, Copy)]
pub struct RingConfig {
    /// Number of size-region slots (max in-flight messages).
    pub nslots: usize,
    /// Buffer region capacity in bytes (multiple of 8).
    pub cap_bytes: usize,
    /// Lock steal threshold — the paper's "short timeout interval".
    pub lock_timeout_ns: u64,
    /// Bound on lock acquisition spins before `PushError::Timeout`.
    pub max_lock_spins: usize,
}

impl Default for RingConfig {
    fn default() -> Self {
        Self {
            nslots: 256,
            cap_bytes: 1 << 20,
            lock_timeout_ns: 50_000, // 50 µs — "short" on an RDMA fabric
            max_lock_spins: 1 << 20,
        }
    }
}

impl RingConfig {
    /// Total region bytes needed for this geometry.
    pub fn region_len(&self) -> usize {
        layout::SIZE_REGION + self.nslots * 8 + self.cap_bytes
    }

    /// Byte offset of size slot `i` (physical).
    pub(crate) fn slot_off(&self, vslot: u64) -> usize {
        layout::SIZE_REGION + ((vslot as usize) % self.nslots) * 8
    }

    /// Byte offset of the buffer region start.
    pub(crate) fn buf_base(&self) -> usize {
        layout::SIZE_REGION + self.nslots * 8
    }

    /// The shared wrap rule: given a virtual offset and frame length,
    /// return (start_virtual, next_virtual). A frame never straddles the
    /// physical end; if it would, both sides skip to the next multiple of
    /// `cap_bytes` (physical offset 0).
    pub(crate) fn wrap(&self, voff: u64, frame_len: usize) -> (u64, u64) {
        let cap = self.cap_bytes as u64;
        let pos = voff % cap;
        let start = if pos + frame_len as u64 > cap {
            voff + (cap - pos) // skip the tail remainder
        } else {
            voff
        };
        (start, start + frame_len as u64)
    }

    /// Physical buffer byte offset for a virtual offset.
    pub(crate) fn phys(&self, voff: u64) -> usize {
        self.buf_base() + (voff % self.cap_bytes as u64) as usize
    }

    /// Frame length (header + payload, padded to 8 bytes).
    pub(crate) fn frame_len(payload_len: usize) -> usize {
        (layout::FRAME_HDR + payload_len + 7) & !7
    }
}

/// Allocate and register a ring buffer region on `fabric`; returns the
/// region id (producers connect QPs to it) and the local region handle
/// (for the co-located consumer).
pub fn create_ring(fabric: &Fabric, config: RingConfig) -> (RegionId, MemoryRegion) {
    assert!(config.cap_bytes % 8 == 0, "capacity must be 8-byte aligned");
    assert!(config.nslots >= 2, "need at least 2 slots");
    let (id, region) = fabric.register(config.region_len());
    // Publish the geometry so senders can reconstruct the config from the
    // region id alone (see `ring_config_of`).
    region.store_u64(layout::NSLOTS, config.nslots as u64);
    region.store_u64(layout::CAP_BYTES, config.cap_bytes as u64);
    (id, region)
}

/// Little-endian u32 from the first 4 bytes of `b`. Panic-free by
/// construction: fewer than 4 bytes (a torn frame header) decodes as 0,
/// which the length/checksum validation downstream rejects exactly like
/// any other corrupt frame — the ring's checksum-discard philosophy,
/// never a worker crash.
pub(crate) fn le_u32(b: &[u8]) -> u32 {
    match b {
        [a, b2, c, d, ..] => u32::from_le_bytes([*a, *b2, *c, *d]),
        _ => 0,
    }
}

/// Reconstruct a ring's geometry from its region (remote senders that
/// only know the region id). Timeout tuning falls back to defaults.
pub fn ring_config_of(fabric: &Fabric, id: RegionId) -> Option<RingConfig> {
    let qp = fabric.connect(id).ok()?;
    let (nslots, _) = qp.post_read_u64(layout::NSLOTS).ok()?;
    let (cap_bytes, _) = qp.post_read_u64(layout::CAP_BYTES).ok()?;
    if nslots < 2 || cap_bytes == 0 {
        return None;
    }
    Some(RingConfig {
        nslots: nslots as usize,
        cap_bytes: cap_bytes as usize,
        ..Default::default()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_len_padding() {
        assert_eq!(RingConfig::frame_len(0), 8);
        assert_eq!(RingConfig::frame_len(1), 16);
        assert_eq!(RingConfig::frame_len(8), 16);
        assert_eq!(RingConfig::frame_len(9), 24);
    }

    #[test]
    fn wrap_rule() {
        let cfg = RingConfig {
            cap_bytes: 64,
            ..Default::default()
        };
        // Fits: no skip.
        assert_eq!(cfg.wrap(0, 16), (0, 16));
        assert_eq!(cfg.wrap(48, 16), (48, 64));
        // Would straddle: skip to next cap boundary.
        assert_eq!(cfg.wrap(56, 16), (64, 80));
        // Exactly at boundary behaves like offset 0.
        assert_eq!(cfg.wrap(64, 16), (64, 80));
    }

    #[test]
    fn wrap_deterministic_for_both_sides() {
        let cfg = RingConfig {
            cap_bytes: 128,
            ..Default::default()
        };
        // Consumer replays producer decisions from (voff, len) alone.
        let mut v_prod = 0u64;
        let mut v_cons = 0u64;
        for len in [16usize, 40, 64, 24, 120, 16, 88] {
            let (s1, n1) = cfg.wrap(v_prod, len);
            let (s2, n2) = cfg.wrap(v_cons, len);
            assert_eq!((s1, n1), (s2, n2));
            v_prod = n1;
            v_cons = n2;
        }
    }

    #[test]
    fn region_len_geometry() {
        let cfg = RingConfig {
            nslots: 4,
            cap_bytes: 256,
            ..Default::default()
        };
        assert_eq!(cfg.region_len(), 64 + 32 + 256);
        assert_eq!(cfg.buf_base(), 96);
        assert_eq!(cfg.slot_off(0), 64);
        assert_eq!(cfg.slot_off(5), 64 + 8); // wraps mod nslots
    }
}
