//! Producer (sender) side of the double-ring buffer.
//!
//! Implements the paper's §6.1 sender operations over one-sided RDMA
//! verbs only, with the e15 **verb-coalesced** data plane:
//!
//! 1. acquire the CAS spin-lock — one verb: the lock word packs a
//!    per-acquisition tag (high 16 bits) and the acquire timestamp
//!    (low 48 bits), so locking, lease-stamping, and (on contention)
//!    lease inspection are all carried by the CAS itself; a holder past
//!    the timeout is stolen exactly as before (the deadlock-resolution
//!    mechanism),
//! 2. **GH** — one vectored read of the four header words; when the
//!    producer's cache from its last successful push still matches the
//!    tail, the size-slot read and the Case-7 recovery scan are skipped
//!    entirely (the cached-header fast path, see below),
//! 3. recover a predecessor lost after WL (busy slot ⇒ advance header
//!    on its behalf — proof Case 7),
//! 4. space check (slot ring + byte ring),
//! 5. **WB** — write the frame(s) into the buffer region; frames are
//!    built in a producer-owned scratch (no allocation in steady state)
//!    and a batched push writes each physically contiguous run of
//!    frames with a single verb,
//! 6. **WL** — CAS the size word (busy bit + length) per frame; a
//!    failed CAS means a lock stealer finalized this slot first
//!    (Cases 2/3/6) — abort (single push) or finalize the accepted
//!    prefix (batched push),
//! 7. **UH** — advance both header tails with one doorbell-batched CAS
//!    pair,
//! 8. unlock (ignoring failure if the lock was stolen meanwhile).
//!
//! ## Cached-header fast path
//!
//! After a push whose UH CAS pair actually advanced the header (a
//! benignly-failed UH means a stealer moved the tail mid-push — the
//! cache is dropped then, or it could alias a tail already holding the
//! stealer's frame), the producer remembers the tail it published
//! (`vtail_off`, `vtail_slot`). The next GH still performs its one
//! vectored header read — that read *is* the validation — and if the
//! tail matches the cache, nobody pushed in between: the slot at the
//! tail is guaranteed clear (or the slot ring is full, which the space
//! check catches from the same read), so the per-slot read and the
//! Case-7 scan are skipped and the WL expectation is 0. A naive variant
//! that skips GH entirely and trusts the WL CAS alone is **unsound**:
//! if other producers pushed and the consumer already drained the slot
//! back to 0, the CAS succeeds on a position the consumer's cursor has
//! passed and the message is silently lost (ABA). The validated-read
//! variant closes that hole at the cost of one verb, and any mismatch
//! or WL failure falls back to the full GH scan on the next attempt.
//!
//! [`ProducerSession`] exposes each protocol step separately so the
//! liveness tests can interleave two producers in every Case 1–8 order
//! (including mid-batch deaths via [`ProducerSession::wl_at`]);
//! [`RingProducer::push`] / [`RingProducer::push_many`] are the
//! production paths driving a session straight through, with optional
//! fault injection ([`DieAt`]).

use super::{layout, FrameKind, RingConfig};
use crate::rdma::{retry_verb, QueuePair, RdmaError};
use crate::util::{frame_checksum, Clock};
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Fault injection point: the producer "dies" (abandons the protocol,
/// leaving partial state) after completing the named step. For
/// `push_many`, `AfterWb` means after the coalesced frame write and
/// `AfterWl` after the *last* slot CAS; deaths between individual WLs
/// are driven through the stepped [`ProducerSession::wl_at`] API.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DieAt {
    AfterLock,
    AfterGh,
    AfterWb,
    AfterWl,
    AfterUh,
}

/// Why a push did not complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PushError {
    /// Not enough slot or byte space (caller may retry after consumption).
    Full,
    /// Lock could not be acquired within `max_lock_spins`.
    Timeout,
    /// A lock stealer finalized our slot first (WL CAS failed); the
    /// payload may have corrupted the winner's frame — the consumer's
    /// checksum will catch that. Retryable.
    LostRace,
    /// Injected fault: producer abandoned the protocol after this step.
    Died(DieAt),
    /// Underlying (simulated) fabric error.
    Fabric(String),
}

impl From<RdmaError> for PushError {
    fn from(e: RdmaError) -> Self {
        PushError::Fabric(e.to_string())
    }
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full => write!(f, "ring full"),
            PushError::Timeout => write!(f, "lock acquisition timed out"),
            PushError::LostRace => write!(f, "lost slot race to a lock stealer"),
            PushError::Died(s) => write!(f, "producer died after {s:?}"),
            PushError::Fabric(e) => write!(f, "fabric: {e}"),
        }
    }
}

impl std::error::Error for PushError {}

/// Successful push summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PushOutcome {
    /// Virtual slot the message landed in.
    pub vslot: u64,
    /// Total modelled fabric time spent on the verbs.
    pub simulated_ns: u64,
    /// Whether the lock was stolen from a timed-out holder.
    pub stole_lock: bool,
    /// One-sided verbs issued by this push (doorbell batches count 1).
    pub verbs: u64,
    /// Whether the cached-header fast path skipped the full GH scan.
    pub cache_hit: bool,
}

/// Successful (possibly partial) batched push summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPushOutcome {
    /// Frames actually published — always a *prefix* of the input (the
    /// ring filled, or a lock stealer took the remaining slots). The
    /// caller re-offers the tail through its own retry/recovery path.
    pub accepted: usize,
    /// Virtual slot of the first published frame.
    pub first_vslot: u64,
    /// Total modelled fabric time spent on the verbs.
    pub simulated_ns: u64,
    /// Whether the lock was stolen from a timed-out holder.
    pub stole_lock: bool,
    /// One-sided verbs issued (doorbell batches count 1).
    pub verbs: u64,
    /// Whether the cached-header fast path skipped the full GH scan.
    pub cache_hit: bool,
}

/// Lock word layout: acquisition tag (high 16 bits, never 0 while
/// held) and acquire timestamp (low 48 bits of the producer clock's
/// nanoseconds). The steal check measures the hold modulo 2^48 (~78 h),
/// so clock wraps never leave a dead holder's lock unstealable; only a
/// hold longer than a full wrap aliases (steal deferred, still bounded).
const LOCK_TS_MASK: u64 = (1 << 48) - 1;

/// Per-acquisition-attempt tag counter. Unlock/steal CAS on the *exact*
/// packed word, so correctness needs the word to differ between any two
/// concurrent holders of one lock: a fresh tag per attempt makes a
/// collision require both a 65535-attempt counter wrap *and* an
/// identical masked timestamp (producer ids, which callers may reuse at
/// scale, never enter the word).
static LOCK_TAG: AtomicU64 = AtomicU64::new(0);

fn lock_word(now_ns: u64) -> u64 {
    let tag = (LOCK_TAG.fetch_add(1, Ordering::Relaxed) % 0xFFFF) + 1; // 1..=0xFFFF
    (tag << 48) | (now_ns & LOCK_TS_MASK)
}

/// Tail snapshot a producer keeps from its last successful push.
#[derive(Debug, Clone, Copy)]
struct HeaderCache {
    vtail_off: u64,
    vtail_slot: u64,
}

/// A sender bound to one ring via a queue pair.
///
/// Owns the reusable frame scratch and the header cache, so it is
/// `Send` but **not** `Sync` — one producer per sending thread (the
/// protocol's producer id uniqueness already requires that).
pub struct RingProducer {
    qp: QueuePair,
    config: RingConfig,
    clock: Arc<dyn Clock>,
    /// Non-zero, unique per producer (frame attribution; the lock word
    /// itself carries a per-acquisition tag, not this id).
    id: u64,
    /// Frame-build scratch, reused across pushes (zero-alloc steady
    /// state: `wb`/`wb_many` never allocate once warm).
    scratch: RefCell<Vec<u8>>,
    /// Cached tail from the last successful push (fast-path GH).
    cache: Cell<Option<HeaderCache>>,
    /// Fast path enable (benches compare against the uncached protocol).
    caching: Cell<bool>,
}

impl RingProducer {
    /// `id` must be non-zero and unique among producers of this ring.
    pub fn new(qp: QueuePair, config: RingConfig, clock: Arc<dyn Clock>, id: u64) -> Self {
        assert!(id != 0, "producer id must be non-zero");
        Self {
            qp,
            config,
            clock,
            id,
            scratch: RefCell::new(Vec::new()),
            cache: Cell::new(None),
            caching: Cell::new(true),
        }
    }

    /// Producer id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// True if a payload of `len` bytes can *ever* fit this ring (its
    /// frame is no larger than the byte-ring capacity). A `false` here
    /// means `Full` is permanent for this payload — retrying is futile.
    pub fn accepts(&self, len: usize) -> bool {
        RingConfig::frame_len(len) <= self.config.cap_bytes
    }

    /// Enable/disable the cached-header fast path (default on). The
    /// protocol is identical either way; benches disable it to measure
    /// the uncoalesced baseline.
    pub fn set_caching(&self, on: bool) {
        self.caching.set(on);
        if !on {
            self.cache.set(None);
        }
    }

    /// Full protocol push. `die_at` injects a mid-protocol failure.
    ///
    /// A `LostRace` on a cached-header attempt is retried **once**
    /// through the full GH scan (the failed WL already invalidated the
    /// cache): a ghost busy word left by a producer that died after WL
    /// needs the Case-7 recovery pass the fast path skipped, and the
    /// old uncached push resolved that case internally — callers keep
    /// seeing `LostRace` only for genuine mid-push steals.
    pub fn push(&self, payload: &[u8], die_at: Option<DieAt>) -> Result<PushOutcome, PushError> {
        self.push_frame(payload, FrameKind::Eager, die_at)
    }

    /// [`RingProducer::push`] with an explicit frame kind: a
    /// `Descriptor` push carries a rendezvous descriptor as the frame
    /// body and publishes the `FRAME_DESC` bit with the same WL CAS.
    /// The protocol (and every failure case) is identical.
    pub fn push_frame(
        &self,
        payload: &[u8],
        kind: FrameKind,
        die_at: Option<DieAt>,
    ) -> Result<PushOutcome, PushError> {
        let had_cache = self.caching.get() && self.cache.get().is_some();
        match self.push_protocol(payload, kind, die_at) {
            Err(PushError::LostRace) if had_cache => self.push_protocol(payload, kind, die_at),
            r => r,
        }
    }

    fn push_protocol(
        &self,
        payload: &[u8],
        kind: FrameKind,
        die_at: Option<DieAt>,
    ) -> Result<PushOutcome, PushError> {
        let mut s = self.begin()?;
        macro_rules! die_check {
            ($point:expr) => {
                if die_at == Some($point) {
                    return Err(PushError::Died($point));
                }
            };
        }
        die_check!(DieAt::AfterLock);
        s.gh()?;
        die_check!(DieAt::AfterGh);
        s.reserve(payload.len())?;
        s.set_kind(kind);
        s.wb(payload)?;
        die_check!(DieAt::AfterWb);
        s.wl()?;
        die_check!(DieAt::AfterWl);
        s.uh()?;
        die_check!(DieAt::AfterUh);
        s.unlock()?;
        // Record the cache only when OUR UH advanced the header: a
        // benignly-failed UH means a lock stealer moved the tail during
        // this push, and the tail can land exactly where we would have
        // put it while the slot there already holds the stealer's
        // frame — a cache recorded then would pass the next push's
        // validation read and WB over a committed entry.
        if s.uh_ok {
            self.cache.set(Some(HeaderCache {
                vtail_off: s.next_v,
                vtail_slot: s.vtail_slot + 1,
            }));
        } else {
            self.cache.set(None);
        }
        Ok(s.outcome())
    }

    /// Batched push: one lock acquisition, one GH, one reservation walk
    /// over all frames (the wrap rule applies per frame, exactly as
    /// sequential pushes would place them), one coalesced WB per
    /// physically contiguous run, per-slot WLs, one doorbell-batched
    /// UH, one unlock.
    ///
    /// Partial failure is a *prefix*: if the ring fills mid-batch (or a
    /// stealer takes a later slot), the accepted prefix is published
    /// and counted in [`BatchPushOutcome::accepted`]; the caller
    /// retries or strands the tail through its recovery path.
    /// `push_many(&[f])` leaves byte-identical ring state to `push(f)`.
    /// Like [`RingProducer::push`], a `LostRace` on a cached-header
    /// attempt is retried once through the full GH scan.
    pub fn push_many(
        &self,
        payloads: &[&[u8]],
        die_at: Option<DieAt>,
    ) -> Result<BatchPushOutcome, PushError> {
        self.push_many_frames(payloads, &[], die_at)
    }

    /// [`RingProducer::push_many`] with per-frame kinds, so one batch
    /// can mix eager payloads and rendezvous descriptors. `kinds` is
    /// either empty (all eager) or exactly `payloads.len()` long; the
    /// accepted-prefix contract is unchanged.
    pub fn push_many_frames(
        &self,
        payloads: &[&[u8]],
        kinds: &[FrameKind],
        die_at: Option<DieAt>,
    ) -> Result<BatchPushOutcome, PushError> {
        assert!(
            kinds.is_empty() || kinds.len() == payloads.len(),
            "kinds must be empty (all eager) or match payloads"
        );
        let had_cache = self.caching.get() && self.cache.get().is_some();
        match self.push_many_protocol(payloads, kinds, die_at) {
            Err(PushError::LostRace) if had_cache => {
                self.push_many_protocol(payloads, kinds, die_at)
            }
            r => r,
        }
    }

    fn push_many_protocol(
        &self,
        payloads: &[&[u8]],
        kinds: &[FrameKind],
        die_at: Option<DieAt>,
    ) -> Result<BatchPushOutcome, PushError> {
        if payloads.is_empty() {
            return Ok(BatchPushOutcome {
                accepted: 0,
                first_vslot: 0,
                simulated_ns: 0,
                stole_lock: false,
                verbs: 0,
                cache_hit: false,
            });
        }
        let mut s = self.begin()?;
        macro_rules! die_check {
            ($point:expr) => {
                if die_at == Some($point) {
                    return Err(PushError::Died($point));
                }
            };
        }
        die_check!(DieAt::AfterLock);
        s.gh()?;
        die_check!(DieAt::AfterGh);
        let accepted = s.reserve_many(payloads)?;
        s.set_kinds(kinds);
        s.wb_many(&payloads[..accepted])?;
        die_check!(DieAt::AfterWb);
        let accepted = s.wl_many()?;
        die_check!(DieAt::AfterWl);
        s.uh_many()?;
        die_check!(DieAt::AfterUh);
        s.unlock()?;
        // Same UH-success gate as `push` (see there).
        if s.uh_ok {
            self.cache.set(Some(HeaderCache {
                vtail_off: s.batch_end_v,
                vtail_slot: s.vtail_slot + accepted as u64,
            }));
        } else {
            self.cache.set(None);
        }
        let mut out = s.batch_outcome();
        out.accepted = accepted;
        Ok(out)
    }

    /// Begin a stepped session: acquires the lock (with timeout
    /// stealing). One verb on the uncontended path — the CAS installs
    /// the packed owner+timestamp word; on contention the failed CAS
    /// already returned the holder's word, so the lease check needs no
    /// extra read.
    pub fn begin(&self) -> Result<ProducerSession<'_>, PushError> {
        let mut sim_ns = 0u64;
        let mut verbs = 0u64;
        let mut stole = false;
        for _ in 0..self.config.max_lock_spins {
            let word = lock_word(self.clock.now_ns());
            // Every protocol verb runs under the bounded VerbLost retry
            // (fault plane); a lost verb observably never landed, so
            // re-posting the CAS is safe.
            let (res, out) = retry_verb(&self.qp, self.id, |qp| {
                qp.post_cas(layout::LOCK, 0, word)
            })?;
            sim_ns += out.simulated_ns;
            verbs += 1;
            match res {
                Ok(_) => return Ok(ProducerSession::new(self, sim_ns, verbs, stole, word)),
                Err(prev) => {
                    // Timeout steal: the paper's deadlock resolution.
                    // The holder's acquire timestamp rode back in the
                    // failed CAS result. Elapsed time is computed mod
                    // 2^48 so a clock that wrapped the 48-bit field
                    // still measures the hold correctly (an elapsed
                    // beyond 2^48 ns aliases short — at worst a late
                    // steal deferred to the next wrap, never a stuck
                    // dead lock).
                    let ts = prev & LOCK_TS_MASK;
                    let now = self.clock.now_ns();
                    let elapsed = now.wrapping_sub(ts) & LOCK_TS_MASK;
                    if elapsed > self.config.lock_timeout_ns {
                        let word = lock_word(now);
                        let (res, out) = retry_verb(&self.qp, self.id, |qp| {
                            qp.post_cas(layout::LOCK, prev, word)
                        })?;
                        sim_ns += out.simulated_ns;
                        verbs += 1;
                        if res.is_ok() {
                            stole = true;
                            return Ok(ProducerSession::new(self, sim_ns, verbs, stole, word));
                        }
                    }
                    std::hint::spin_loop();
                }
            }
        }
        Err(PushError::Timeout)
    }
}

/// One in-flight push with explicit protocol steps (GH / WB / WL / UH /
/// unlock) for deterministic interleaving in the liveness tests.
pub struct ProducerSession<'a> {
    prod: &'a RingProducer,
    sim_ns: u64,
    verbs: u64,
    stole_lock: bool,
    /// Exact word we installed in the lock (unlock CASes it back to 0).
    lock_word: u64,
    cache_hit: bool,
    // Header snapshot from GH.
    vtail_off: u64,
    vtail_slot: u64,
    vhead_slot: u64,
    vhead_off: u64,
    /// Size word observed at the tail slot during GH (WL CAS expectation).
    observed_size_word: u64,
    // Single-push reservation.
    start_v: u64,
    next_v: u64,
    frame_len: usize,
    payload_len: usize,
    // Batched reservation: per-frame (start_v, frame_len) and the
    // virtual offset one past the last accepted frame.
    batch: Vec<(u64, usize)>,
    batch_end_v: u64,
    /// Size-word kind bit for the single-push WL (0 = eager).
    kind_bit: u64,
    /// Per-frame kind bits for the batched WLs (empty = all eager).
    batch_kind_bits: Vec<u64>,
    /// True iff the UH CAS pair actually advanced the header (both
    /// compares matched the GH snapshot). A benignly-failed UH means a
    /// stealer moved the tail during our push — the producer cache must
    /// NOT be recorded then (see the push drivers).
    uh_ok: bool,
    done_gh: bool,
    done_reserve: bool,
}

impl<'a> Drop for ProducerSession<'a> {
    fn drop(&mut self) {
        // The lock-order witness releases here, not in `unlock()`: a
        // session abandoned mid-protocol (fault injection, steal) leaves
        // the *remote* lock word set by design, but this thread no longer
        // holds anything in the ordering sense once the session dies.
        crate::lint::runtime::ring_lock_released(self.prod.qp.region_id().0);
    }
}

impl<'a> ProducerSession<'a> {
    fn new(prod: &'a RingProducer, sim_ns: u64, verbs: u64, stole_lock: bool, lock_word: u64) -> Self {
        // Witness the spin-lock acquisition (rank check only; the lease
        // steal bounds waiting, so no wait-for edges are recorded).
        crate::lint::runtime::ring_lock_acquired(prod.qp.region_id().0);
        Self {
            prod,
            sim_ns,
            verbs,
            stole_lock,
            lock_word,
            cache_hit: false,
            vtail_off: 0,
            vtail_slot: 0,
            vhead_slot: 0,
            vhead_off: 0,
            observed_size_word: 0,
            start_v: 0,
            next_v: 0,
            frame_len: 0,
            payload_len: 0,
            batch: Vec::new(),
            batch_end_v: 0,
            kind_bit: 0,
            batch_kind_bits: Vec::new(),
            uh_ok: false,
            done_gh: false,
            done_reserve: false,
        }
    }

    fn qp(&self) -> &QueuePair {
        &self.prod.qp
    }

    /// Run one protocol verb under the bounded VerbLost retry (seeded by
    /// the producer id so concurrent producers' backoffs desynchronize).
    /// Exhaustion surfaces as `PushError::Fabric` via `?` at the call
    /// sites, which the senders above fold into drop/strand/recovery.
    fn rv<T>(
        &self,
        op: impl FnMut(&QueuePair) -> Result<T, RdmaError>,
    ) -> Result<T, RdmaError> {
        retry_verb(&self.prod.qp, self.prod.id, op)
    }

    fn cfg(&self) -> &RingConfig {
        &self.prod.config
    }

    /// True if this session's GH took the cached-header fast path.
    pub fn used_cache(&self) -> bool {
        self.cache_hit
    }

    /// Set the frame kind the next [`ProducerSession::wl`] publishes
    /// (default eager). Kind rides the WL CAS, so call before it.
    pub fn set_kind(&mut self, kind: FrameKind) {
        self.kind_bit = kind.bit();
    }

    /// Per-frame kinds for the batched WLs; empty = all eager. Extra
    /// entries past the accepted prefix are ignored.
    pub fn set_kinds(&mut self, kinds: &[FrameKind]) {
        self.batch_kind_bits.clear();
        self.batch_kind_bits.extend(kinds.iter().map(|k| k.bit()));
    }

    /// GH: one vectored read of the four header words. If the tail
    /// matches this producer's cache from its last successful push,
    /// nothing was pushed in between — skip the size-slot read and the
    /// Case-7 scan (the fast path; see the module docs for why the
    /// validation read is load-bearing). Otherwise read the tail slot
    /// and recover any predecessor lost after WL (Case 7) by advancing
    /// the header on its behalf.
    pub fn gh(&mut self) -> Result<(), PushError> {
        let mut hdr = [0u64; 4];
        let out = self.rv(|qp| qp.post_read_words(layout::VTAIL_OFF, &mut hdr))?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        self.vtail_off = hdr[0];
        self.vtail_slot = hdr[1];
        self.vhead_slot = hdr[2];
        self.vhead_off = hdr[3];

        if self.prod.caching.get() {
            if let Some(c) = self.prod.cache.get() {
                if c.vtail_off == self.vtail_off
                    && c.vtail_slot == self.vtail_slot
                    && self.vhead_slot <= self.vtail_slot
                {
                    // Tail unchanged since our own push completed: the
                    // tail slot was left clear by the consumer (or the
                    // slot ring is full, which `reserve` rejects from
                    // the head/tail distance in this same snapshot).
                    self.observed_size_word = 0;
                    self.cache_hit = true;
                    self.done_gh = true;
                    return Ok(());
                }
            }
        }

        // The consumer may already have consumed entries the header never
        // covered (a producer lost after WL whose entry the consumer read
        // before anyone ran Case-7 recovery). The head is then *ahead* of
        // the tail; fast-forward the tail to re-synchronize (both tail
        // words ride one vectored write).
        if self.vhead_slot > self.vtail_slot {
            self.vtail_slot = self.vhead_slot;
            self.vtail_off = self.vhead_off;
            let out = self
                .rv(|qp| qp.post_write_words(layout::VTAIL_OFF, &[self.vtail_off, self.vtail_slot]))?;
            self.sim_ns += out.simulated_ns;
            self.verbs += 1;
        }

        // Case-7 recovery loop: a sender lost after WL leaves a busy slot
        // the header does not cover yet. Advance on its behalf (UH) so the
        // consumer will reach it; bounded by nslots.
        //
        // Crucially, a busy word at the tail position is only a *lost*
        // entry if the slot ring is not full: when
        // `vtail_slot - vhead_slot == nslots`, the busy word belongs to
        // the oldest unconsumed entry (virtual slot `vtail_slot - nslots`)
        // and must not be skipped.
        for _ in 0..self.cfg().nslots {
            if self.vtail_slot - self.vhead_slot >= self.cfg().nslots as u64 {
                self.observed_size_word = layout::BUSY; // full; reserve() rejects
                break;
            }
            let slot_off = self.cfg().slot_off(self.vtail_slot);
            let (word, out) = self.rv(|qp| qp.post_read_u64(slot_off))?;
            self.sim_ns += out.simulated_ns;
            self.verbs += 1;
            if word & layout::BUSY == 0 {
                self.observed_size_word = word;
                break;
            }
            let flen = (word & layout::LEN_MASK) as usize;
            let (_, next) = self.cfg().wrap(self.vtail_off, flen);
            let out = self
                .rv(|qp| qp.post_write_words(layout::VTAIL_OFF, &[next, self.vtail_slot + 1]))?;
            self.sim_ns += out.simulated_ns;
            self.verbs += 1;
            self.vtail_off = next;
            self.vtail_slot += 1;
        }
        self.done_gh = true;
        Ok(())
    }

    /// Space check + placement decision for a payload of `len` bytes.
    pub fn reserve(&mut self, len: usize) -> Result<(), PushError> {
        assert!(self.done_gh, "reserve before gh");
        let frame_len = RingConfig::frame_len(len);
        if frame_len > self.cfg().cap_bytes {
            self.abort_unlock();
            return Err(PushError::Full); // can never fit
        }
        // Slot ring full?
        if self.vtail_slot - self.vhead_slot >= self.cfg().nslots as u64 {
            self.abort_unlock();
            return Err(PushError::Full);
        }
        // Byte ring full? (virtual-offset distance includes skip padding)
        let (start_v, next_v) = self.cfg().wrap(self.vtail_off, frame_len);
        if next_v - self.vhead_off > self.cfg().cap_bytes as u64 {
            self.abort_unlock();
            return Err(PushError::Full);
        }
        self.start_v = start_v;
        self.next_v = next_v;
        self.frame_len = frame_len;
        self.payload_len = len;
        self.done_reserve = true;
        Ok(())
    }

    /// Batched space check: walk the payloads through the wrap rule,
    /// accepting the longest prefix that fits both rings. Returns the
    /// accepted count (≥ 1), or `Full` (after releasing the lock) when
    /// not even the first frame fits.
    pub fn reserve_many(&mut self, payloads: &[&[u8]]) -> Result<usize, PushError> {
        assert!(self.done_gh, "reserve_many before gh");
        self.batch.clear();
        self.batch.reserve(payloads.len());
        let mut voff = self.vtail_off;
        for (i, p) in payloads.iter().enumerate() {
            let frame_len = RingConfig::frame_len(p.len());
            if frame_len > self.cfg().cap_bytes {
                break; // this frame can never fit; accept the prefix
            }
            if self.vtail_slot + i as u64 - self.vhead_slot >= self.cfg().nslots as u64 {
                break; // slot ring full
            }
            let (start_v, next_v) = self.cfg().wrap(voff, frame_len);
            if next_v - self.vhead_off > self.cfg().cap_bytes as u64 {
                break; // byte ring full
            }
            self.batch.push((start_v, frame_len));
            voff = next_v;
        }
        if self.batch.is_empty() {
            self.abort_unlock();
            return Err(PushError::Full);
        }
        self.batch_end_v = voff;
        self.done_reserve = true;
        Ok(self.batch.len())
    }

    /// Build one frame (`[len][crc][payload][pad]`) into `buf`.
    fn build_frame(buf: &mut Vec<u8>, payload: &[u8], frame_len: usize) {
        let base = buf.len();
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&frame_checksum(payload).to_le_bytes());
        buf.extend_from_slice(payload);
        buf.resize(base + frame_len, 0);
    }

    /// WB: write the frame into the buffer region. The frame is built
    /// in the producer's reusable scratch — no allocation once warm.
    pub fn wb(&mut self, payload: &[u8]) -> Result<(), PushError> {
        assert!(self.done_reserve, "wb before reserve");
        assert_eq!(payload.len(), self.payload_len, "payload changed size");
        let mut frame = self.prod.scratch.borrow_mut();
        frame.clear();
        Self::build_frame(&mut frame, payload, self.frame_len);
        let off = self.cfg().phys(self.start_v);
        let out = self.rv(|qp| qp.post_write(off, &frame))?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        Ok(())
    }

    /// Batched WB: concatenate each *physically contiguous* run of
    /// reserved frames in the scratch and write it with a single verb.
    /// A batch spans at most one wrap boundary (its total size is
    /// bounded by the ring capacity), so this is one or two verbs.
    pub fn wb_many(&mut self, payloads: &[&[u8]]) -> Result<(), PushError> {
        assert!(self.done_reserve, "wb_many before reserve_many");
        assert!(
            payloads.len() >= self.batch.len(),
            "wb_many needs every reserved payload"
        );
        let mut frame = self.prod.scratch.borrow_mut();
        frame.clear();
        let mut run_phys = 0usize;
        for i in 0..self.batch.len() {
            let (start_v, frame_len) = self.batch[i];
            let phys = self.cfg().phys(start_v);
            if !frame.is_empty() && phys != run_phys + frame.len() {
                // Wrap boundary: flush the finished run.
                let out = self.rv(|qp| qp.post_write(run_phys, &frame))?;
                self.sim_ns += out.simulated_ns;
                self.verbs += 1;
                frame.clear();
            }
            if frame.is_empty() {
                run_phys = phys;
            }
            Self::build_frame(&mut frame, payloads[i], frame_len);
        }
        if !frame.is_empty() {
            let out = self.rv(|qp| qp.post_write(run_phys, &frame))?;
            self.sim_ns += out.simulated_ns;
            self.verbs += 1;
        }
        Ok(())
    }

    /// WL: CAS the size word to (busy | frame_len). Failure means a lock
    /// stealer already finalized this slot (paper Cases 2/3/6): abort.
    pub fn wl(&mut self) -> Result<(), PushError> {
        assert!(self.done_reserve, "wl before reserve");
        let slot_off = self.cfg().slot_off(self.vtail_slot);
        let new_word = layout::BUSY | self.kind_bit | self.frame_len as u64;
        let (res, out) =
            self.rv(|qp| qp.post_cas(slot_off, self.observed_size_word, new_word))?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        if res.is_err() {
            // Invalidate the header cache: the retry must run the full
            // GH scan (the winner moved the tail, or a ghost busy word
            // needs the Case-7 recovery pass).
            self.prod.cache.set(None);
            self.abort_unlock();
            return Err(PushError::LostRace);
        }
        Ok(())
    }

    /// WL for the `i`-th reserved frame of a batch (stepped API — the
    /// liveness tests die between individual slot CASes with this).
    pub fn wl_at(&mut self, i: usize) -> Result<(), PushError> {
        assert!(self.done_reserve, "wl_at before reserve_many");
        let (_, frame_len) = self.batch[i];
        let slot_off = self.cfg().slot_off(self.vtail_slot + i as u64);
        let expected = if i == 0 { self.observed_size_word } else { 0 };
        let kind_bit = self.batch_kind_bits.get(i).copied().unwrap_or(0);
        let new_word = layout::BUSY | kind_bit | frame_len as u64;
        let (res, out) = self.rv(|qp| qp.post_cas(slot_off, expected, new_word))?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        if res.is_err() {
            return Err(PushError::LostRace);
        }
        Ok(())
    }

    /// Batched WL: one CAS per reserved slot. A failure at slot `i > 0`
    /// truncates the batch to the published prefix `i` (the stealer owns
    /// the rest); a failure at slot 0 aborts like [`ProducerSession::wl`].
    pub fn wl_many(&mut self) -> Result<usize, PushError> {
        for i in 0..self.batch.len() {
            if self.wl_at(i).is_err() {
                self.prod.cache.set(None);
                if i == 0 {
                    self.abort_unlock();
                    return Err(PushError::LostRace);
                }
                self.batch.truncate(i);
                let (s, l) = self.batch[i - 1];
                self.batch_end_v = s + l as u64;
                return Ok(i);
            }
        }
        Ok(self.batch.len())
    }

    /// UH: advance both header tails with one doorbell-batched CAS pair,
    /// expecting the GH snapshot; a failed compare means another
    /// producer (racing on a stolen lock) already advanced identically —
    /// benign (Cases 4/8).
    pub fn uh(&mut self) -> Result<(), PushError> {
        let ((r1, r2), out) = self.rv(|qp| {
            qp.post_cas_pair(
                layout::VTAIL_OFF,
                self.vtail_off,
                self.next_v,
                layout::VTAIL_SLOT,
                self.vtail_slot,
                self.vtail_slot + 1,
            )
        })?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        self.uh_ok = r1.is_ok() && r2.is_ok();
        Ok(())
    }

    /// UH for the accepted batch prefix (one verb).
    pub fn uh_many(&mut self) -> Result<(), PushError> {
        let ((r1, r2), out) = self.rv(|qp| {
            qp.post_cas_pair(
                layout::VTAIL_OFF,
                self.vtail_off,
                self.batch_end_v,
                layout::VTAIL_SLOT,
                self.vtail_slot,
                self.vtail_slot + self.batch.len() as u64,
            )
        })?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        self.uh_ok = r1.is_ok() && r2.is_ok();
        Ok(())
    }

    /// Release the lock if we still own it (a stealer may hold it now).
    pub fn unlock(&mut self) -> Result<(), PushError> {
        let (_, out) = self.rv(|qp| qp.post_cas(layout::LOCK, self.lock_word, 0))?;
        self.sim_ns += out.simulated_ns;
        self.verbs += 1;
        Ok(())
    }

    fn abort_unlock(&mut self) {
        let _ = self.rv(|qp| qp.post_cas(layout::LOCK, self.lock_word, 0));
    }

    /// Where this session's frame was (or would be) placed.
    pub fn placement(&self) -> (u64, u64) {
        (self.start_v, self.vtail_slot)
    }

    /// Completed-push summary.
    pub fn outcome(&self) -> PushOutcome {
        PushOutcome {
            vslot: self.vtail_slot,
            simulated_ns: self.sim_ns,
            stole_lock: self.stole_lock,
            verbs: self.verbs,
            cache_hit: self.cache_hit,
        }
    }

    /// Completed-batch summary (`accepted` is filled by the driver).
    fn batch_outcome(&self) -> BatchPushOutcome {
        BatchPushOutcome {
            accepted: self.batch.len(),
            first_vslot: self.vtail_slot,
            simulated_ns: self.sim_ns,
            stole_lock: self.stole_lock,
            verbs: self.verbs,
            cache_hit: self.cache_hit,
        }
    }
}
