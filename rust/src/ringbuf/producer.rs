//! Producer (sender) side of the double-ring buffer.
//!
//! Implements the paper's §6.1 sender operations over one-sided RDMA
//! verbs only:
//!
//! 1. acquire the CAS spin-lock (stealing it if held longer than the
//!    timeout — the deadlock-resolution mechanism),
//! 2. **GH** — read the header and the size slot at the tail,
//! 3. recover a predecessor lost after WL (busy slot ⇒ advance header
//!    on its behalf — proof Case 7),
//! 4. space check (slot ring + byte ring),
//! 5. **WB** — write the frame into the buffer region,
//! 6. **WL** — CAS the size word (busy bit + length); a failed CAS means
//!    a lock stealer finalized this slot first (Cases 2/3/6) — abort,
//! 7. **UH** — advance the header tails,
//! 8. unlock (ignoring failure if the lock was stolen meanwhile).
//!
//! [`ProducerSession`] exposes each protocol step separately so the
//! liveness tests can interleave two producers in every Case 1–8 order;
//! [`RingProducer::push`] is the production path driving a session
//! straight through, with optional fault injection ([`DieAt`]).

use super::{layout, RingConfig};
use crate::rdma::{QueuePair, RdmaError};
use crate::util::{frame_checksum, Clock};
use std::sync::Arc;

/// Fault injection point: the producer "dies" (abandons the protocol,
/// leaving partial state) after completing the named step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DieAt {
    AfterLock,
    AfterGh,
    AfterWb,
    AfterWl,
    AfterUh,
}

/// Why a push did not complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PushError {
    /// Not enough slot or byte space (caller may retry after consumption).
    Full,
    /// Lock could not be acquired within `max_lock_spins`.
    Timeout,
    /// A lock stealer finalized our slot first (WL CAS failed); the
    /// payload may have corrupted the winner's frame — the consumer's
    /// checksum will catch that. Retryable.
    LostRace,
    /// Injected fault: producer abandoned the protocol after this step.
    Died(DieAt),
    /// Underlying (simulated) fabric error.
    Fabric(String),
}

impl From<RdmaError> for PushError {
    fn from(e: RdmaError) -> Self {
        PushError::Fabric(e.to_string())
    }
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full => write!(f, "ring full"),
            PushError::Timeout => write!(f, "lock acquisition timed out"),
            PushError::LostRace => write!(f, "lost slot race to a lock stealer"),
            PushError::Died(s) => write!(f, "producer died after {s:?}"),
            PushError::Fabric(e) => write!(f, "fabric: {e}"),
        }
    }
}

impl std::error::Error for PushError {}

/// Successful push summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PushOutcome {
    /// Virtual slot the message landed in.
    pub vslot: u64,
    /// Total modelled fabric time spent on the verbs.
    pub simulated_ns: u64,
    /// Whether the lock was stolen from a timed-out holder.
    pub stole_lock: bool,
}

/// A sender bound to one ring via a queue pair.
pub struct RingProducer {
    qp: QueuePair,
    config: RingConfig,
    clock: Arc<dyn Clock>,
    /// Non-zero, unique per producer (lock ownership word).
    id: u64,
}

impl RingProducer {
    /// `id` must be non-zero and unique among producers of this ring.
    pub fn new(qp: QueuePair, config: RingConfig, clock: Arc<dyn Clock>, id: u64) -> Self {
        assert!(id != 0, "producer id 0 is the unlocked sentinel");
        Self { qp, config, clock, id }
    }

    /// Producer id (lock word value while held).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Full protocol push. `die_at` injects a mid-protocol failure.
    pub fn push(&self, payload: &[u8], die_at: Option<DieAt>) -> Result<PushOutcome, PushError> {
        let mut s = self.begin()?;
        macro_rules! die_check {
            ($point:expr) => {
                if die_at == Some($point) {
                    return Err(PushError::Died($point));
                }
            };
        }
        die_check!(DieAt::AfterLock);
        s.gh()?;
        die_check!(DieAt::AfterGh);
        s.reserve(payload.len())?;
        s.wb(payload)?;
        die_check!(DieAt::AfterWb);
        s.wl()?;
        die_check!(DieAt::AfterWl);
        s.uh()?;
        die_check!(DieAt::AfterUh);
        s.unlock()?;
        Ok(s.outcome())
    }

    /// Begin a stepped session: acquires the lock (with timeout stealing).
    pub fn begin(&self) -> Result<ProducerSession<'_>, PushError> {
        let mut sim_ns = 0u64;
        let mut stole = false;
        for _ in 0..self.config.max_lock_spins {
            let (res, out) = self.qp.post_cas(layout::LOCK, 0, self.id)?;
            sim_ns += out.simulated_ns;
            match res {
                Ok(_) => {
                    let out = self
                        .qp
                        .post_write_u64(layout::LOCK_TS, self.clock.now_ns())?;
                    sim_ns += out.simulated_ns;
                    return Ok(ProducerSession::new(self, sim_ns, stole));
                }
                Err(owner) => {
                    // Timeout steal: the paper's deadlock resolution.
                    let (ts, out) = self.qp.post_read_u64(layout::LOCK_TS)?;
                    sim_ns += out.simulated_ns;
                    let now = self.clock.now_ns();
                    if now.saturating_sub(ts) > self.config.lock_timeout_ns {
                        let (res, out) = self.qp.post_cas(layout::LOCK, owner, self.id)?;
                        sim_ns += out.simulated_ns;
                        if res.is_ok() {
                            stole = true;
                            let out = self.qp.post_write_u64(layout::LOCK_TS, now)?;
                            sim_ns += out.simulated_ns;
                            return Ok(ProducerSession::new(self, sim_ns, stole));
                        }
                    }
                    std::hint::spin_loop();
                }
            }
        }
        Err(PushError::Timeout)
    }
}

/// One in-flight push with explicit protocol steps (GH / WB / WL / UH /
/// unlock) for deterministic interleaving in the liveness tests.
pub struct ProducerSession<'a> {
    prod: &'a RingProducer,
    sim_ns: u64,
    stole_lock: bool,
    // Header snapshot from GH.
    vtail_off: u64,
    vtail_slot: u64,
    vhead_slot: u64,
    vhead_off: u64,
    /// Size word observed at the tail slot during GH (WL CAS expectation).
    observed_size_word: u64,
    // Reservation.
    start_v: u64,
    next_v: u64,
    frame_len: usize,
    payload_len: usize,
    done_gh: bool,
    done_reserve: bool,
}

impl<'a> ProducerSession<'a> {
    fn new(prod: &'a RingProducer, sim_ns: u64, stole_lock: bool) -> Self {
        Self {
            prod,
            sim_ns,
            stole_lock,
            vtail_off: 0,
            vtail_slot: 0,
            vhead_slot: 0,
            vhead_off: 0,
            observed_size_word: 0,
            start_v: 0,
            next_v: 0,
            frame_len: 0,
            payload_len: 0,
            done_gh: false,
            done_reserve: false,
        }
    }

    fn qp(&self) -> &QueuePair {
        &self.prod.qp
    }

    fn cfg(&self) -> &RingConfig {
        &self.prod.config
    }

    /// GH: read the header and the size slot at the tail; recover any
    /// predecessor lost after WL (Case 7) by advancing the header first.
    pub fn gh(&mut self) -> Result<(), PushError> {
        let mut read = |off: usize| -> Result<u64, PushError> {
            let (v, out) = self.prod.qp.post_read_u64(off)?;
            self.sim_ns += out.simulated_ns;
            Ok(v)
        };
        self.vtail_off = read(layout::VTAIL_OFF)?;
        self.vtail_slot = read(layout::VTAIL_SLOT)?;
        self.vhead_slot = read(layout::VHEAD_SLOT)?;
        self.vhead_off = read(layout::VHEAD_OFF)?;

        // The consumer may already have consumed entries the header never
        // covered (a producer lost after WL whose entry the consumer read
        // before anyone ran Case-7 recovery). The head is then *ahead* of
        // the tail; fast-forward the tail to re-synchronize.
        if self.vhead_slot > self.vtail_slot {
            self.vtail_slot = self.vhead_slot;
            self.vtail_off = self.vhead_off;
            let out = self.qp().post_write_u64(layout::VTAIL_OFF, self.vtail_off)?;
            self.sim_ns += out.simulated_ns;
            let out = self
                .qp()
                .post_write_u64(layout::VTAIL_SLOT, self.vtail_slot)?;
            self.sim_ns += out.simulated_ns;
        }

        // Case-7 recovery loop: a sender lost after WL leaves a busy slot
        // the header does not cover yet. Advance on its behalf (UH) so the
        // consumer will reach it; bounded by nslots.
        //
        // Crucially, a busy word at the tail position is only a *lost*
        // entry if the slot ring is not full: when
        // `vtail_slot - vhead_slot == nslots`, the busy word belongs to
        // the oldest unconsumed entry (virtual slot `vtail_slot - nslots`)
        // and must not be skipped.
        for _ in 0..self.cfg().nslots {
            if self.vtail_slot - self.vhead_slot >= self.cfg().nslots as u64 {
                self.observed_size_word = layout::BUSY; // full; reserve() rejects
                break;
            }
            let slot_off = self.cfg().slot_off(self.vtail_slot);
            let (word, out) = self.qp().post_read_u64(slot_off)?;
            self.sim_ns += out.simulated_ns;
            if word & layout::BUSY == 0 {
                self.observed_size_word = word;
                break;
            }
            let flen = (word & !layout::BUSY) as usize;
            let (_, next) = self.cfg().wrap(self.vtail_off, flen);
            let out = self.qp().post_write_u64(layout::VTAIL_OFF, next)?;
            self.sim_ns += out.simulated_ns;
            let out = self
                .qp()
                .post_write_u64(layout::VTAIL_SLOT, self.vtail_slot + 1)?;
            self.sim_ns += out.simulated_ns;
            self.vtail_off = next;
            self.vtail_slot += 1;
        }
        self.done_gh = true;
        Ok(())
    }

    /// Space check + placement decision for a payload of `len` bytes.
    pub fn reserve(&mut self, len: usize) -> Result<(), PushError> {
        assert!(self.done_gh, "reserve before gh");
        let frame_len = RingConfig::frame_len(len);
        if frame_len > self.cfg().cap_bytes {
            return Err(PushError::Full); // can never fit
        }
        // Slot ring full?
        if self.vtail_slot - self.vhead_slot >= self.cfg().nslots as u64 {
            self.abort_unlock();
            return Err(PushError::Full);
        }
        // Byte ring full? (virtual-offset distance includes skip padding)
        let (start_v, next_v) = self.cfg().wrap(self.vtail_off, frame_len);
        if next_v - self.vhead_off > self.cfg().cap_bytes as u64 {
            self.abort_unlock();
            return Err(PushError::Full);
        }
        self.start_v = start_v;
        self.next_v = next_v;
        self.frame_len = frame_len;
        self.payload_len = len;
        self.done_reserve = true;
        Ok(())
    }

    /// WB: write the frame (`[len][crc][payload][pad]`) into the buffer.
    pub fn wb(&mut self, payload: &[u8]) -> Result<(), PushError> {
        assert!(self.done_reserve, "wb before reserve");
        assert_eq!(payload.len(), self.payload_len, "payload changed size");
        let mut frame = Vec::with_capacity(self.frame_len);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&frame_checksum(payload).to_le_bytes());
        frame.extend_from_slice(payload);
        frame.resize(self.frame_len, 0);
        let off = self.cfg().phys(self.start_v);
        let out = self.qp().post_write(off, &frame)?;
        self.sim_ns += out.simulated_ns;
        Ok(())
    }

    /// WL: CAS the size word to (busy | frame_len). Failure means a lock
    /// stealer already finalized this slot (paper Cases 2/3/6): abort.
    pub fn wl(&mut self) -> Result<(), PushError> {
        assert!(self.done_reserve, "wl before reserve");
        let slot_off = self.cfg().slot_off(self.vtail_slot);
        let new_word = layout::BUSY | self.frame_len as u64;
        let (res, out) = self
            .qp()
            .post_cas(slot_off, self.observed_size_word, new_word)?;
        self.sim_ns += out.simulated_ns;
        if res.is_err() {
            self.abort_unlock();
            return Err(PushError::LostRace);
        }
        Ok(())
    }

    /// UH: advance the header tails. Uses CAS with the GH-snapshot as the
    /// expectation; a failed CAS means another producer (racing on a
    /// stolen lock) already advanced identically — benign (Cases 4/8).
    pub fn uh(&mut self) -> Result<(), PushError> {
        let (_, out) = self
            .qp()
            .post_cas(layout::VTAIL_OFF, self.vtail_off, self.next_v)?;
        self.sim_ns += out.simulated_ns;
        let (_, out) = self
            .qp()
            .post_cas(layout::VTAIL_SLOT, self.vtail_slot, self.vtail_slot + 1)?;
        self.sim_ns += out.simulated_ns;
        Ok(())
    }

    /// Release the lock if we still own it (a stealer may hold it now).
    pub fn unlock(&mut self) -> Result<(), PushError> {
        let (_, out) = self.qp().post_cas(layout::LOCK, self.prod.id, 0)?;
        self.sim_ns += out.simulated_ns;
        Ok(())
    }

    fn abort_unlock(&mut self) {
        let _ = self.qp().post_cas(layout::LOCK, self.prod.id, 0);
    }

    /// Where this session's frame was (or would be) placed.
    pub fn placement(&self) -> (u64, u64) {
        (self.start_v, self.vtail_slot)
    }

    /// Completed-push summary.
    pub fn outcome(&self) -> PushOutcome {
        PushOutcome {
            vslot: self.vtail_slot,
            simulated_ns: self.sim_ns,
            stole_lock: self.stole_lock,
        }
    }
}
