//! Consumer (receiver) side of the double-ring buffer.
//!
//! Wait-free (§6.1: "whenever new data is available in memory, it can be
//! processed immediately"): `pop` does a bounded number of local reads,
//! one busy-bit clear and two header stores — no locks, no retries, and
//! it can never be blocked by a failed producer. The consumer is
//! co-located with the region, so it uses the local [`MemoryRegion`]
//! handle directly rather than a queue pair.
//!
//! Corruption handling: a frame whose CRC32 (or length field) does not
//! match is reported as [`PopError::Corrupted`] and *skipped using the
//! size-region length* — the consumer always advances along the same
//! logical path the producers took (Theorem 2), so one delayed writer can
//! poison at most the entry it collided on, never the consumer's cursor.

use super::{layout, FrameKind, RingConfig};
use crate::rdma::MemoryRegion;
use crate::util::frame_checksum;

/// One consumed ring entry: the frame body plus its kind bit. For an
/// `Eager` frame the payload is the message; for a `Descriptor` frame
/// it is an encoded [`crate::rdma::PayloadDescriptor`] the transport
/// layer resolves with a one-sided read.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

/// A poisoned entry (skipped; cursor already advanced past it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PopError {
    /// CRC or length mismatch — a delayed writer overwrote this frame
    /// after losing the slot race (paper Cases 2/5/6).
    Corrupted {
        vslot: u64,
        reason: &'static str,
    },
}

impl std::fmt::Display for PopError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PopError::Corrupted { vslot, reason } => {
                write!(f, "corrupted entry at slot {vslot}: {reason}")
            }
        }
    }
}

impl std::error::Error for PopError {}

/// The single consumer of a ring.
pub struct RingConsumer {
    region: MemoryRegion,
    config: RingConfig,
    // Local cursor cache (authoritative copies live in the header so
    // producers can read them for space checks).
    vhead_slot: u64,
    vhead_off: u64,
    scratch: Vec<u8>,
}

impl RingConsumer {
    /// Attach to a ring region (must be the co-located owner).
    pub fn new(region: MemoryRegion, config: RingConfig) -> Self {
        let vhead_slot = region.load_u64(layout::VHEAD_SLOT);
        let vhead_off = region.load_u64(layout::VHEAD_OFF);
        Self {
            region,
            config,
            vhead_slot,
            vhead_off,
            scratch: Vec::new(),
        }
    }

    /// Try to consume the next message. `None` = ring empty. Kind-blind
    /// view of [`RingConsumer::pop_frame`]: the payload bytes are
    /// returned whatever the frame kind (eager callers that never push
    /// descriptors see exactly the old behaviour).
    pub fn pop(&mut self) -> Option<Result<Vec<u8>, PopError>> {
        self.pop_frame()
            .map(|r| r.map(|f| f.payload))
    }

    /// Try to consume the next frame, kind included. `None` = ring empty.
    pub fn pop_frame(&mut self) -> Option<Result<Frame, PopError>> {
        let slot_off = self.config.slot_off(self.vhead_slot);
        let word = self.region.load_u64(slot_off);
        if word & layout::BUSY == 0 {
            return None; // nothing published at our cursor
        }
        let kind = if word & layout::FRAME_DESC != 0 {
            FrameKind::Descriptor
        } else {
            FrameKind::Eager
        };
        let frame_len = (word & layout::LEN_MASK) as usize;
        let vslot = self.vhead_slot;

        // Defensive sanity on the producer-written length. A valid WL can
        // only write frame_len in [16, cap]; anything else is protocol
        // corruption — skip the slot without moving the byte cursor (the
        // next producer GH/WL pair re-synchronizes via virtual offsets).
        if frame_len < layout::FRAME_HDR
            || frame_len % 8 != 0
            || frame_len > self.config.cap_bytes
        {
            self.clear_and_advance(slot_off, self.vhead_off);
            return Some(Err(PopError::Corrupted { vslot, reason: "bad size word" }));
        }

        let (start_v, next_v) = self.config.wrap(self.vhead_off, frame_len);
        let phys = self.config.phys(start_v);
        self.scratch.resize(frame_len, 0);
        self.region.read_bytes(phys, &mut self.scratch);

        let payload_len = super::le_u32(&self.scratch) as usize;
        let stored_crc = super::le_u32(&self.scratch[4..]);

        if payload_len + layout::FRAME_HDR > frame_len {
            self.clear_and_advance(slot_off, next_v);
            return Some(Err(PopError::Corrupted { vslot, reason: "length mismatch" }));
        }
        let payload = &self.scratch[layout::FRAME_HDR..layout::FRAME_HDR + payload_len];
        if frame_checksum(payload) != stored_crc {
            self.clear_and_advance(slot_off, next_v);
            return Some(Err(PopError::Corrupted { vslot, reason: "crc mismatch" }));
        }
        let out = payload.to_vec();
        self.clear_and_advance(slot_off, next_v);
        Some(Ok(Frame { kind, payload: out }))
    }

    /// Clear the busy bit (only the consumer may do this — it is what
    /// guarantees Theorem 2) and publish the advanced head cursor.
    fn clear_and_advance(&mut self, slot_off: usize, next_v: u64) {
        self.region.store_u64(slot_off, 0);
        self.vhead_slot += 1;
        self.vhead_off = next_v;
        self.region.store_u64(layout::VHEAD_SLOT, self.vhead_slot);
        self.region.store_u64(layout::VHEAD_OFF, self.vhead_off);
    }

    /// Batch pop: drain up to `max` entries in one round — an arriving
    /// micro-batch is seen whole, so downstream batch formation isn't
    /// fed one message per poll. Driven purely by the per-slot busy
    /// bits (like [`RingConsumer::pop`], which never reads the producer
    /// tail), so an entry whose producer died between WL and UH is
    /// still drained immediately instead of waiting for a later push's
    /// Case-7 recovery to advance the header.
    pub fn pop_many(&mut self, max: usize) -> Vec<Result<Vec<u8>, PopError>> {
        let mut out = Vec::new();
        for _ in 0..max {
            match self.pop() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Kind-preserving [`RingConsumer::pop_many`]: drains mixed
    /// eager/descriptor batches whole.
    pub fn pop_many_frames(&mut self, max: usize) -> Vec<Result<Frame, PopError>> {
        let mut out = Vec::new();
        for _ in 0..max {
            match self.pop_frame() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Number of published-but-unconsumed entries (approximate; racy read
    /// of the producer tail).
    pub fn backlog(&self) -> u64 {
        self.region
            .load_u64(layout::VTAIL_SLOT)
            .saturating_sub(self.vhead_slot)
    }

    /// Consumer cursor (vslot, voff) — for tests.
    pub fn cursor(&self) -> (u64, u64) {
        (self.vhead_slot, self.vhead_off)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{create_ring, RingProducer};
    use super::*;
    use crate::rdma::Fabric;
    use crate::util::SystemClock;
    use std::sync::Arc;

    fn setup(cfg: RingConfig) -> (RingProducer, RingConsumer) {
        let fabric = Fabric::ideal();
        let (id, region) = create_ring(&fabric, cfg);
        let qp = fabric.connect(id).unwrap();
        let prod = RingProducer::new(qp, cfg, Arc::new(SystemClock), 1);
        let cons = RingConsumer::new(region, cfg);
        (prod, cons)
    }

    #[test]
    fn empty_pop_is_none() {
        let (_p, mut c) = setup(RingConfig::default());
        assert!(c.pop().is_none());
    }

    #[test]
    fn push_pop_roundtrip() {
        let (p, mut c) = setup(RingConfig::default());
        p.push(b"hello", None).unwrap();
        p.push(b"world!!", None).unwrap();
        assert_eq!(c.pop().unwrap().unwrap(), b"hello");
        assert_eq!(c.pop().unwrap().unwrap(), b"world!!");
        assert!(c.pop().is_none());
    }

    #[test]
    fn variable_sizes_roundtrip() {
        let (p, mut c) = setup(RingConfig {
            nslots: 64,
            cap_bytes: 1 << 16,
            ..Default::default()
        });
        let msgs: Vec<Vec<u8>> = (0..50)
            .map(|i| vec![i as u8; (i * 37) % 1000 + 1])
            .collect();
        for m in &msgs {
            p.push(m, None).unwrap();
        }
        for m in &msgs {
            assert_eq!(&c.pop().unwrap().unwrap(), m);
        }
    }

    #[test]
    fn empty_payload() {
        let (p, mut c) = setup(RingConfig::default());
        p.push(b"", None).unwrap();
        assert_eq!(c.pop().unwrap().unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn wraps_around_many_times() {
        let cfg = RingConfig {
            nslots: 8,
            cap_bytes: 256,
            ..Default::default()
        };
        let (p, mut c) = setup(cfg);
        for round in 0..100u32 {
            let msg = round.to_le_bytes().repeat(5 + (round as usize % 17));
            p.push(&msg, None).unwrap();
            assert_eq!(c.pop().unwrap().unwrap(), msg);
        }
        assert!(c.pop().is_none());
    }

    #[test]
    fn slot_ring_full() {
        let cfg = RingConfig {
            nslots: 4,
            cap_bytes: 1 << 16,
            ..Default::default()
        };
        let (p, mut c) = setup(cfg);
        for _ in 0..4 {
            p.push(b"x", None).unwrap();
        }
        assert_eq!(p.push(b"x", None), Err(super::super::PushError::Full));
        // Consuming frees a slot.
        c.pop().unwrap().unwrap();
        p.push(b"x", None).unwrap();
    }

    #[test]
    fn byte_ring_full() {
        let cfg = RingConfig {
            nslots: 64,
            cap_bytes: 128,
            ..Default::default()
        };
        let (p, mut c) = setup(cfg);
        p.push(&[1u8; 56], None).unwrap(); // frame 64
        p.push(&[2u8; 56], None).unwrap(); // frame 64 — buffer now full
        assert_eq!(p.push(&[3u8; 8], None), Err(super::super::PushError::Full));
        assert_eq!(c.pop().unwrap().unwrap(), vec![1u8; 56]);
        p.push(&[3u8; 8], None).unwrap();
        assert_eq!(c.pop().unwrap().unwrap(), vec![2u8; 56]);
        assert_eq!(c.pop().unwrap().unwrap(), vec![3u8; 8]);
    }

    #[test]
    fn oversized_message_rejected() {
        let cfg = RingConfig {
            nslots: 4,
            cap_bytes: 64,
            ..Default::default()
        };
        let (p, _c) = setup(cfg);
        assert_eq!(p.push(&[0u8; 128], None), Err(super::super::PushError::Full));
    }

    #[test]
    fn frame_kinds_roundtrip_and_mix() {
        use super::super::FrameKind;
        let (p, mut c) = setup(RingConfig::default());
        p.push_frame(b"descriptor-body", FrameKind::Descriptor, None).unwrap();
        p.push(b"eager", None).unwrap();
        let f = c.pop_frame().unwrap().unwrap();
        assert_eq!((f.kind, f.payload.as_slice()), (FrameKind::Descriptor, &b"descriptor-body"[..]));
        let f = c.pop_frame().unwrap().unwrap();
        assert_eq!((f.kind, f.payload.as_slice()), (FrameKind::Eager, &b"eager"[..]));
        // One batch mixing kinds: each frame keeps its own bit.
        let payloads: [&[u8]; 3] = [b"a", b"bb", b"ccc"];
        let kinds = [FrameKind::Eager, FrameKind::Descriptor, FrameKind::Eager];
        let out = p.push_many_frames(&payloads, &kinds, None).unwrap();
        assert_eq!(out.accepted, 3);
        let frames: Vec<_> = c.pop_many_frames(8).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(frames.len(), 3);
        for ((f, want_kind), want_payload) in frames.iter().zip(kinds).zip(payloads) {
            assert_eq!(f.kind, want_kind);
            assert_eq!(f.payload, want_payload);
        }
        // Kind-blind pop still sees descriptor bodies as raw bytes.
        p.push_frame(b"raw", FrameKind::Descriptor, None).unwrap();
        assert_eq!(c.pop().unwrap().unwrap(), b"raw");
    }

    #[test]
    fn backlog_tracks() {
        let (p, mut c) = setup(RingConfig::default());
        assert_eq!(c.backlog(), 0);
        p.push(b"a", None).unwrap();
        p.push(b"b", None).unwrap();
        assert_eq!(c.backlog(), 2);
        c.pop().unwrap().unwrap();
        assert_eq!(c.backlog(), 1);
    }
}
