//! Ablation baseline: a conventional **single-ring** buffer (no size
//! region, in-band framing, committed-tail header word).
//!
//! This is what you would build without the paper's contribution. It
//! works under fault-free multi-producer contention, but a producer that
//! dies between reserving space and committing the tail leaves the ring
//! **permanently deadlocked**: later producers cannot distinguish "slow
//! writer" from "dead writer" because there is no per-entry busy bit for
//! a stealer to inspect, and the consumer cannot skip an uncommitted
//! frame because the length metadata is in-band (unwritten). The
//! `tests/ringbuf_liveness.rs` ablation demonstrates exactly this against
//! the double-ring recovery, regenerating DESIGN.md §6's first ablation
//! row.

use super::layout as dlayout;
use crate::rdma::{MemoryRegion, QueuePair, RdmaError};
use crate::util::frame_checksum;

/// Header layout (distinct from the double ring): one lock word, a
/// *reserved* tail (bumped before writing) and a *committed* tail
/// (bumped after writing); consumer head.
mod slayout {
    pub const LOCK: usize = 0;
    pub const TAIL_RESERVED: usize = 8;
    pub const TAIL_COMMITTED: usize = 16;
    pub const HEAD: usize = 24;
    pub const BUF: usize = 32;
}

/// Push failure for the single ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SingleRingPushError {
    Full,
    /// Lock spin bound exhausted — with a dead lock holder this is
    /// permanent: the deadlock the double ring was designed to break.
    Deadlocked,
    Fabric(String),
}

impl From<RdmaError> for SingleRingPushError {
    fn from(e: RdmaError) -> Self {
        SingleRingPushError::Fabric(e.to_string())
    }
}

/// Sender for the single-ring baseline. `cap_bytes` is the buffer size.
pub struct SingleRingProducer {
    qp: QueuePair,
    cap_bytes: usize,
    id: u64,
    max_lock_spins: usize,
}

impl SingleRingProducer {
    pub fn new(qp: QueuePair, cap_bytes: usize, id: u64, max_lock_spins: usize) -> Self {
        assert!(id != 0);
        Self { qp, cap_bytes, id, max_lock_spins }
    }

    /// Required region length for a given capacity.
    pub fn region_len(cap_bytes: usize) -> usize {
        slayout::BUF + cap_bytes
    }

    /// Push; `die_before_commit` simulates the fatal failure mode.
    pub fn push(
        &self,
        payload: &[u8],
        die_before_commit: bool,
    ) -> Result<(), SingleRingPushError> {
        // Acquire lock — NO timeout stealing: without per-entry commit
        // metadata a stealer could not recover a half-written frame.
        let mut acquired = false;
        for _ in 0..self.max_lock_spins {
            let (res, _) = self.qp.post_cas(slayout::LOCK, 0, self.id)?;
            if res.is_ok() {
                acquired = true;
                break;
            }
            std::hint::spin_loop();
        }
        if !acquired {
            return Err(SingleRingPushError::Deadlocked);
        }

        let frame_len = (dlayout::FRAME_HDR + payload.len() + 7) & !7;
        let (tail, _) = self.qp.post_read_u64(slayout::TAIL_RESERVED)?;
        let (head, _) = self.qp.post_read_u64(slayout::HEAD)?;
        let cap = self.cap_bytes as u64;
        let pos = tail % cap;
        let start = if pos + frame_len as u64 > cap { tail + (cap - pos) } else { tail };
        let next = start + frame_len as u64;
        if next - head > cap {
            let _ = self.qp.post_cas(slayout::LOCK, self.id, 0);
            return Err(SingleRingPushError::Full);
        }

        self.qp.post_write_u64(slayout::TAIL_RESERVED, next)?;
        // If we skipped the tail remainder, leave a skip marker so the
        // consumer knows to jump to the boundary (in-band framing has no
        // other way to communicate the skip — one of the exact
        // variable-size-message weaknesses the double ring's size region
        // eliminates).
        if start != tail && cap - pos >= 8 {
            let mut marker = [0u8; 8];
            marker[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
            self.qp
                .post_write(slayout::BUF + pos as usize, &marker)?;
        }
        let mut frame = Vec::with_capacity(frame_len);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&frame_checksum(payload).to_le_bytes());
        frame.extend_from_slice(payload);
        frame.resize(frame_len, 0);
        self.qp
            .post_write(slayout::BUF + (start % cap) as usize, &frame)?;

        if die_before_commit {
            // Producer dies holding the lock with TAIL_COMMITTED stale:
            // every later producer spins forever; the consumer stalls at
            // the committed tail. Permanent deadlock.
            return Ok(());
        }

        self.qp.post_write_u64(slayout::TAIL_COMMITTED, next)?;
        let _ = self.qp.post_cas(slayout::LOCK, self.id, 0);
        Ok(())
    }
}

/// Consumer for the single-ring baseline.
pub struct SingleRingConsumer {
    region: MemoryRegion,
    cap_bytes: usize,
    head: u64,
}

impl SingleRingConsumer {
    pub fn new(region: MemoryRegion, cap_bytes: usize) -> Self {
        let head = region.load_u64(slayout::HEAD);
        Self { region, cap_bytes, head }
    }

    /// Pop the next committed frame, if any.
    pub fn pop(&mut self) -> Option<Vec<u8>> {
        let committed = self.region.load_u64(slayout::TAIL_COMMITTED);
        if self.head >= committed {
            return None;
        }
        let cap = self.cap_bytes as u64;
        // Peek the length. If the tail remainder cannot hold a header, or
        // holds a skip marker (len == u32::MAX), jump to the boundary.
        let mut pos = self.head % cap;
        if pos + dlayout::FRAME_HDR as u64 > cap {
            self.head += cap - pos;
            pos = 0;
        }
        let mut hdr = [0u8; 8];
        self.region
            .read_bytes(slayout::BUF + pos as usize, &mut hdr);
        let mut payload_len = super::le_u32(&hdr);
        if payload_len == u32::MAX {
            self.head += cap - pos;
            self.region.read_bytes(slayout::BUF, &mut hdr);
            payload_len = super::le_u32(&hdr);
        }
        let payload_len = payload_len as usize;
        let frame_len = (dlayout::FRAME_HDR + payload_len + 7) & !7;
        let start = self.head;
        let mut frame = vec![0u8; frame_len];
        self.region
            .read_bytes(slayout::BUF + (start % cap) as usize, &mut frame);
        let payload = frame[dlayout::FRAME_HDR..dlayout::FRAME_HDR + payload_len].to_vec();
        self.head = start + frame_len as u64;
        self.region.store_u64(slayout::HEAD, self.head);
        Some(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdma::Fabric;

    fn setup(cap: usize) -> (SingleRingProducer, SingleRingConsumer, Fabric) {
        let fabric = Fabric::ideal();
        let (id, region) = fabric.register(SingleRingProducer::region_len(cap));
        let qp = fabric.connect(id).unwrap();
        (
            SingleRingProducer::new(qp, cap, 1, 10_000),
            SingleRingConsumer::new(region, cap),
            fabric,
        )
    }

    #[test]
    fn roundtrip() {
        let (p, mut c, _) = setup(1 << 16);
        p.push(b"abc", false).unwrap();
        p.push(b"defgh", false).unwrap();
        assert_eq!(c.pop().unwrap(), b"abc");
        assert_eq!(c.pop().unwrap(), b"defgh");
        assert!(c.pop().is_none());
    }

    #[test]
    fn dead_producer_deadlocks_everyone() {
        let (p, mut c, fabric) = setup(1 << 16);
        p.push(b"committed", false).unwrap();
        p.push(b"never-committed", true).unwrap(); // dies holding lock

        // Consumer sees only the committed frame, then stalls forever.
        assert_eq!(c.pop().unwrap(), b"committed");
        assert!(c.pop().is_none());

        // Any other producer spins out: permanent deadlock.
        let qp2 = fabric.connect(crate::rdma::RegionId(0)).unwrap();
        let p2 = SingleRingProducer::new(qp2, 1 << 16, 2, 1000);
        assert_eq!(
            p2.push(b"blocked", false),
            Err(SingleRingPushError::Deadlocked)
        );
    }

    #[test]
    fn wraps() {
        let (p, mut c, _) = setup(128);
        for i in 0..50u8 {
            p.push(&[i; 40], false).unwrap();
            assert_eq!(c.pop().unwrap(), vec![i; 40]);
        }
    }
}
