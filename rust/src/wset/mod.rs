//! Workflow Set assembly (§3.1) and the multi-set router (§3.2).
//!
//! A [`WorkflowSet`] wires one region's worth of components onto a single
//! simulated RDMA fabric: a NodeManager (+ replica cluster), proxies,
//! workflow instances per stage (Theorem-1 sized), a replicated database
//! layer and an idle pool. [`MultiSet`] spreads clients across several
//! sets: submissions go to a random set, and a fast-reject from one set
//! sends the client to the next (§3.2 — "clients that receive a rejection
//! then attempt to submit their request to a different RDMA-enabled
//! set"), which is also the fault-isolation boundary.
//!
//! Both tiers serve through the unified [`crate::client::Gateway`] API:
//! `submit_with(app, payload, SubmitOptions)` returns a typed
//! [`crate::client::RequestHandle`] with priorities, deadlines, blocking
//! `wait()`, and `cancel()`.
//!
//! [`MultiSet`] is the paper's *client-side* policy. The server-side
//! alternative — a global load-aware router with cross-set spill and
//! elastic instance donation — lives in [`crate::federation`] and uses
//! the per-set elasticity hooks here ([`WorkflowSet::add_idle_instance`]
//! / [`WorkflowSet::retire_idle_instance`]).
//!
//! **Worker fault tolerance**: with `nm.instance_timeout_ms` set, the
//! housekeeping timer runs the [`RecoverySweep`] — dead instances
//! (silent heartbeats) are evicted, their stages refilled from the idle
//! pool / a donor stage, and their in-flight requests replayed from
//! per-stage checkpoints, with `Failed` tombstones once the submit
//! `RetryPolicy` budget is exhausted. `chaos.kill_every_ms` turns the
//! same timer into a crash injector for fault drills
//! ([`WorkflowSet::inject_crash`] does it deterministically).

mod recovery;

pub use recovery::RecoverySweep;

use crate::client::{
    Gateway, RequestHandle, RequestTracker, SubmitError, SubmitOptions,
};
use crate::config::{ClusterConfig, ExecModel};
use crate::db::{DbClient, MemDb};
use crate::metrics::Registry;
use crate::nm::{NmCluster, NodeManager, StageKey};
use crate::pipeline::{plan_chain, StageReq};
use crate::proxy::Proxy;
use crate::metrics::Counter;
use crate::rdma::{Fabric, FabricConfig, FaultPlan, FaultStats, LatencyModel};
use crate::ringbuf::RingConfig;
use crate::runtime::{ExecutorPool, PjrtRuntime, StageExecutor};
use crate::transport::{AppId, Payload};
use crate::util::{NodeId, Rng, SystemClock};
use crate::workflow::{AppLogic, CrashHandle, Instance, InstanceConfig};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-instance crash switches, shared between the set and its
/// housekeeper's chaos driver.
type CrashRegistry = Arc<Mutex<Vec<(NodeId, CrashHandle)>>>;

/// Registry handles for the fault-plane counters — created **only** when
/// the config has a `faults` block, so an unfaulted build's
/// `counters_snapshot` never grows a fault row. The fabric keeps the
/// authoritative cumulative [`FaultStats`]; these mirror it.
#[derive(Clone)]
struct FaultCounters {
    verbs_lost: Arc<Counter>,
    verbs_delayed: Arc<Counter>,
    region_flaps: Arc<Counter>,
    partitioned_ops: Arc<Counter>,
    verb_retries: Arc<Counter>,
}

impl FaultCounters {
    fn from_registry(r: &Registry) -> Self {
        Self {
            verbs_lost: r.counter("verbs_lost_total"),
            verbs_delayed: r.counter("verbs_delayed_total"),
            region_flaps: r.counter("region_flaps_total"),
            partitioned_ops: r.counter("partitioned_ops_total"),
            verb_retries: r.counter("verb_retries_total"),
        }
    }

    /// Raise each counter to the fabric's cumulative value. Counters are
    /// written only through this mirror, so `get()` is the last mirrored
    /// value and the delta-add is idempotent across callers.
    fn mirror(&self, s: FaultStats) {
        self.verbs_lost.add(s.verbs_lost.saturating_sub(self.verbs_lost.get()));
        self.verbs_delayed.add(s.verbs_delayed.saturating_sub(self.verbs_delayed.get()));
        self.region_flaps.add(s.region_flaps.saturating_sub(self.region_flaps.get()));
        self.partitioned_ops
            .add(s.partitioned_ops.saturating_sub(self.partitioned_ops.get()));
        self.verb_retries.add(s.verb_retries.saturating_sub(self.verb_retries.get()));
    }
}

/// Map the config `faults` block onto the fabric's [`FaultPlan`].
fn fault_plan_of(f: &crate::config::FaultSettings) -> FaultPlan {
    FaultPlan {
        verb_loss_prob: f.verb_loss_prob,
        delay_prob: f.delay_prob,
        delay_ns: f.delay_ns,
        flap_prob: f.flap_prob,
        partition_after_ops: f.partition_after_ops,
        partition_ops: f.partition_ops,
        partition_group: f.partition_group,
        partition_victim: f.partition_victim,
        seed: f.seed,
    }
}

/// A fully wired Workflow Set.
pub struct WorkflowSet {
    pub fabric: Fabric,
    pub nm: Arc<NodeManager>,
    pub nm_cluster: Arc<NmCluster>,
    pub proxy: Proxy,
    pub dbs: Vec<Arc<MemDb>>,
    pub db_client: Arc<DbClient>,
    instances: Vec<Instance>,
    next_node: u32,
    config: ClusterConfig,
    ring: RingConfig,
    pool: ExecutorPool,
    logic: Arc<dyn AppLogic>,
    tracker: Arc<RequestTracker>,
    metrics: Registry,
    /// Set-wide artifact cache (`cache` config block; `None` = off and
    /// the whole data path is byte-identical to an uncached build).
    cache: Option<Arc<crate::cache::ArtifactCache>>,
    /// Distributed-tracing facade (`trace` config block; `None` = off:
    /// no recorder exists, no `trace_*` counter is registered, and every
    /// component's record site is a skipped `if let`).
    tracer: Option<Arc<crate::trace::Tracer>>,
    /// Set-level hook for request-scoped events recorded outside any
    /// instance (federation routing).
    trace_hook: Option<crate::trace::TraceHook>,
    housekeeper: Option<std::thread::JoinHandle<()>>,
    hk_stop: Arc<std::sync::atomic::AtomicBool>,
    /// Crash switches per instance, shared with the housekeeper's chaos
    /// driver (`chaos.kill_every_ms`) and the public crash-injection
    /// API.
    crash_handles: CrashRegistry,
    /// Rebalance actions taken by the housekeeping loop (§8.2 timer).
    pub auto_rebalances: Arc<std::sync::atomic::AtomicU64>,
    /// Fault-plane counter mirror (`faults` config block; `None` = off
    /// and no fault counter ever appears in the registry).
    fault_counters: Option<FaultCounters>,
}

impl WorkflowSet {
    /// Build a set: `instances_per_stage[app_idx][stage_idx]` instance
    /// counts (use [`WorkflowSet::theorem1_counts`] for balanced
    /// pipelines), plus `idle` spare instances.
    pub fn build(
        config: ClusterConfig,
        instances_per_stage: Vec<Vec<usize>>,
        logic: Arc<dyn AppLogic>,
        pool: ExecutorPool,
    ) -> Self {
        config.validate().expect("invalid config");
        // Fault plane (`faults` block): mapped onto the fabric for every
        // fabric kind; `None` allocates no fault state at all.
        let faults = config.faults.as_ref().map(fault_plan_of);
        let fabric = match config.fabric {
            crate::config::FabricKind::Ideal => Fabric::new(FabricConfig {
                latency: None,
                faults,
                ..Default::default()
            }),
            crate::config::FabricKind::Infiniband100g => Fabric::new(FabricConfig {
                latency: Some(LatencyModel::infiniband_100g()),
                faults,
                ..Default::default()
            }),
            crate::config::FabricKind::TcpDatacenter => Fabric::new(FabricConfig {
                latency: Some(LatencyModel::tcp_datacenter()),
                faults,
                ..Default::default()
            }),
        };
        let clock: Arc<dyn crate::util::Clock> = Arc::new(SystemClock);

        // The NM hands out assignments with each stage's *effective*
        // batch settings materialized (per-stage `batch` block, else the
        // top-level one; never for CM stages) so instances receive a
        // ready policy.
        let nm = Arc::new(NodeManager::new(
            config.apps_with_effective_batch(),
            config.nm.util_threshold,
        ));
        let nm_nodes: Vec<NodeId> = (9000..9000 + config.nm.replicas as u32)
            .map(NodeId)
            .collect();
        let nm_cluster = Arc::new(NmCluster::new(
            nm_nodes.clone(),
            clock.clone(),
            config.nm.heartbeat_timeout_ms * 1_000_000,
        ));
        nm_cluster.elect(nm_nodes[0]).expect("initial NM election");

        // Database layer.
        let dbs: Vec<Arc<MemDb>> = (0..config.db.replicas)
            .map(|_| Arc::new(MemDb::new(clock.clone(), config.db.ttl_ms * 1_000_000)))
            .collect();
        let db_client = Arc::new(DbClient::new(dbs.clone()));

        // Request-lifecycle control plane + metrics, shared by the proxy
        // (per-priority accept/reject counters), the tracker
        // (cancellation / deadline counters), and the instances.
        let metrics = Registry::new();
        let tracker = Arc::new(RequestTracker::new(clock.clone(), metrics.clone()));

        // Content-addressed artifact cache: built only when the config
        // has a `cache` block; shared by the proxy (workflow tier), every
        // instance (per-stage tier) and the housekeeper (TTL sweep).
        let cache = config.cache.as_ref().map(|cs| {
            Arc::new(crate::cache::ArtifactCache::new(
                fabric.clone(),
                clock.clone(),
                cs,
                &metrics,
            ))
        });

        // Distributed tracing: built only when the config has a `trace`
        // block. Every traced component registers its own flight
        // recorder through `Tracer::hook`; the housekeeper drains them
        // on its sweep tick so completed traces surface without any
        // reader in the loop.
        let tracer = config
            .trace
            .as_ref()
            .map(|ts| crate::trace::Tracer::new(ts, clock.clone(), 0, &metrics));

        let ring = RingConfig {
            nslots: config.ring.nslots,
            cap_bytes: config.ring.cap_bytes,
            lock_timeout_ns: config.ring.lock_timeout_us * 1_000,
            ..Default::default()
        };

        let hk_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let auto_rebalances = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let crash_handles: CrashRegistry = Arc::new(Mutex::new(Vec::new()));
        let fault_counters = config
            .faults
            .as_ref()
            .map(|_| FaultCounters::from_registry(&metrics));
        let mut set = Self {
            fabric: fabric.clone(),
            nm: nm.clone(),
            nm_cluster: nm_cluster.clone(),
            proxy: Proxy::new(
                NodeId(1),
                fabric.clone(),
                nm.clone(),
                db_client.clone(),
                clock.clone(),
                &config.proxy,
                tracker.clone(),
                metrics.clone(),
                config.nm.instance_timeout_ms > 0,
            ),
            dbs: dbs.clone(),
            db_client,
            instances: Vec::new(),
            next_node: 100,
            config: config.clone(),
            ring,
            pool: pool.clone(),
            logic: logic.clone(),
            tracker: tracker.clone(),
            metrics,
            cache: cache.clone(),
            tracer: tracer.clone(),
            trace_hook: tracer.as_ref().map(|t| t.hook(0)),
            housekeeper: None,
            hk_stop: hk_stop.clone(),
            crash_handles: crash_handles.clone(),
            auto_rebalances: auto_rebalances.clone(),
            fault_counters: fault_counters.clone(),
        };
        set.proxy
            .set_rendezvous_threshold(config.rdma.rendezvous_threshold_bytes);
        if let Some(c) = &cache {
            set.proxy.set_cache(c.clone());
        }
        if let Some(t) = &tracer {
            // The proxy records admission-side events; the tracker
            // records the failure-family terminal verdicts (cancelled /
            // deadline-exceeded / failed) the data plane never sees.
            set.proxy.set_trace(t.hook(1));
            tracker.set_trace(t.hook(0));
        }

        // Spawn instances: assigned stages first, then the idle pool.
        for (ai, app) in config.apps.iter().enumerate() {
            let counts = &instances_per_stage[ai];
            for (si, &count) in counts.iter().enumerate() {
                for _ in 0..count {
                    let node = set.spawn_instance(ring);
                    nm.assign(node, Some(StageKey { app: AppId(app.id), stage: si as u32 }));
                }
            }
        }
        for _ in 0..config.idle_pool {
            set.spawn_instance(ring);
        }

        // Housekeeping loop (the paper's timers): NM primary heartbeats
        // (§8.1), periodic §8.2 rebalancing, DB TTL purge (§3.4), the
        // tracker sweep for lost requests (§9 message loss would
        // otherwise leak entries), the worker-failure detector +
        // recovery sweep (when `nm.instance_timeout_ms` enables it), and
        // the chaos driver (when `chaos.kill_every_ms` enables it).
        let heartbeat = Duration::from_millis(config.nm.heartbeat_ms);
        let auto_rebalance = config.nm.auto_rebalance;
        let tracker_ttl_ns = config.db.ttl_ms * 1_000_000;
        let instance_timeout_ns = config.nm.instance_timeout_ms * 1_000_000;
        let chaos = config.chaos;
        let mut recovery = RecoverySweep::new(
            nm.clone(),
            tracker.clone(),
            dbs.clone(),
            set.db_client.clone(),
            fabric.clone(),
            clock.clone(),
            instance_timeout_ns,
            &set.metrics,
        );
        recovery.set_rendezvous_threshold(config.rdma.rendezvous_threshold_bytes);
        if let Some(t) = &tracer {
            recovery.set_trace(t.hook(2));
        }
        let chaos_kills = set.metrics.counter("chaos_kills");
        let hk_handles = crash_handles.clone();
        let hk_cache = cache;
        let hk_tracer = tracer;
        let hk_faults = fault_counters;
        let hk_fabric = fabric.clone();
        set.housekeeper = Some(std::thread::spawn(move || {
            let mut last_sweep = std::time::Instant::now();
            let mut last_kill = std::time::Instant::now();
            let kill_every = Duration::from_millis(chaos.kill_every_ms.max(1));
            let mut chaos_rng = Rng::new(chaos.seed);
            while !hk_stop.load(std::sync::atomic::Ordering::SeqCst) {
                if let Some(primary) = nm_cluster.primary() {
                    nm_cluster.heartbeat(primary);
                }
                if chaos.kill_every_ms > 0 && last_kill.elapsed() >= kill_every {
                    // Chaos: kill one random live *assigned* instance
                    // (idle-pool spares are the repair path, not the
                    // victim pool).
                    let assigned: std::collections::HashSet<NodeId> = nm
                        .instances()
                        .into_iter()
                        .filter(|i| i.role.is_some())
                        .map(|i| i.node)
                        .collect();
                    let handles = hk_handles.lock().unwrap();
                    let victims: Vec<&(NodeId, CrashHandle)> = handles
                        .iter()
                        .filter(|(n, h)| assigned.contains(n) && !h.is_crashed())
                        .collect();
                    if !victims.is_empty() {
                        let idx = chaos_rng.below(victims.len() as u64) as usize;
                        victims[idx].1.kill();
                        chaos_kills.inc();
                    }
                    last_kill = std::time::Instant::now();
                }
                if last_sweep.elapsed() > heartbeat * 5 {
                    if instance_timeout_ns > 0 {
                        recovery.sweep();
                    }
                    if auto_rebalance && nm.rebalance().is_some() {
                        auto_rebalances.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    for db in &dbs {
                        db.purge_expired();
                    }
                    if let Some(c) = &hk_cache {
                        c.purge_expired();
                    }
                    if let Some(t) = &hk_tracer {
                        t.drain();
                    }
                    if let Some(fc) = &hk_faults {
                        if let Some(s) = hk_fabric.fault_stats() {
                            fc.mirror(s);
                        }
                    }
                    tracker.purge_older_than(tracker_ttl_ns);
                    last_sweep = std::time::Instant::now();
                }
                std::thread::sleep(heartbeat);
            }
        }));
        set
    }

    fn spawn_instance(&mut self, ring: RingConfig) -> NodeId {
        let node = NodeId(self.next_node);
        self.next_node += 1;
        let clock: Arc<dyn crate::util::Clock> = Arc::new(SystemClock);
        let inst = Instance::spawn(
            InstanceConfig {
                node,
                ring,
                control_poll: Duration::from_millis(5),
                util_window: Duration::from_millis(self.config.nm.util_window_ms),
                // Checkpoints are only useful (and only paid for) when
                // the failure detector can replay them.
                checkpointing: self.config.nm.instance_timeout_ms > 0,
                max_workers: self
                    .config
                    .apps
                    .iter()
                    .flat_map(|a| a.stages.iter().map(|s| s.workers))
                    .max()
                    .unwrap_or(1),
                // The aging guard rides the batch blocks (it guards the
                // same Batch-band backlog batching creates); per-stage
                // overrides count too — the queue is instance-wide, so
                // the strongest configured bound wins.
                max_starvation: Duration::from_millis(
                    self.config.effective_max_starvation_ms(),
                ),
                rendezvous_threshold: self.config.rdma.rendezvous_threshold_bytes,
                cache: self.cache.clone(),
                trace: self.tracer.as_ref().map(|t| t.hook(node.0)),
            },
            &self.fabric,
            self.nm.clone(),
            self.logic.clone(),
            self.pool.clone(),
            self.dbs.clone(),
            self.tracker.clone(),
            clock,
        );
        self.nm.register_instance(node, inst.region_id());
        self.crash_handles
            .lock()
            .unwrap()
            .push((node, inst.crash_handle()));
        self.instances.push(inst);
        node
    }

    /// Theorem-1 instance counts for an app config, given the entrance
    /// instance count.
    pub fn theorem1_counts(app: &crate::config::AppConfig, entrance: usize) -> Vec<usize> {
        let reqs: Vec<StageReq> = app
            .stages
            .iter()
            .map(|s| StageReq {
                name: s.name.clone(),
                exec_s: s.exec_ms / 1000.0,
                gpus_per_instance: s.gpus_per_instance,
                workers: s.workers,
            })
            .collect();
        plan_chain(&reqs, entrance)
            .stages
            .iter()
            .map(|p| p.instances)
            .collect()
    }

    /// Build a set that constructs its **own** executor pool (one pool
    /// per set, the federation deployment shape) instead of sharing a
    /// process-global pool across sets.
    pub fn build_standalone(
        config: ClusterConfig,
        instances_per_stage: Vec<Vec<usize>>,
        logic: Arc<dyn AppLogic>,
        runtime: Option<Arc<PjrtRuntime>>,
    ) -> Self {
        let pool = build_pool(&config, runtime);
        Self::build(config, instances_per_stage, logic, pool)
    }

    /// One admission attempt through the set's proxy — no gateway retry
    /// policy applied. On rejection the payload rides back with the error
    /// so multi-set callers can fall through to a sibling **without
    /// cloning** it up front. Most callers want the [`Gateway`] impl.
    pub fn submit_once(
        &self,
        app: AppId,
        payload: Payload,
        opts: &SubmitOptions,
    ) -> Result<crate::util::Uid, (SubmitError, Payload)> {
        self.proxy.submit_request(app, payload, opts)
    }

    /// Build the typed handle for a UID this set admitted. `set_idx` is
    /// the caller-visible set index (0 for a standalone set; the
    /// accepting index for multi-set / federation tiers).
    pub fn handle_for(
        &self,
        uid: crate::util::Uid,
        set_idx: usize,
        opts: &SubmitOptions,
    ) -> RequestHandle {
        let mut h =
            RequestHandle::new(uid, set_idx, self.tracker.clone(), self.db_client.clone(), opts);
        if let Some(t) = &self.tracer {
            h.attach_tracer(t.clone());
        }
        h
    }

    /// The set's request-lifecycle control plane.
    pub fn tracker(&self) -> &Arc<RequestTracker> {
        &self.tracker
    }

    /// The set's metrics registry: per-priority `accepted.*`/`rejected.*`
    /// from the proxy, `requests_cancelled` / `deadline_missed` /
    /// `requests_failed` from the tracker, and the fault-tolerance
    /// counters `instances_failed` / `instances_replaced` /
    /// `requests_recovered` / `chaos_kills` plus the
    /// `recovery_latency_ns` histogram from the recovery sweep.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// The set's cluster configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// The set's artifact cache, when the config enables one.
    pub fn cache(&self) -> Option<&Arc<crate::cache::ArtifactCache>> {
        self.cache.as_ref()
    }

    /// Cumulative fabric fault-plane counters, when the `faults` config
    /// block (or a manual partition) installed a fault plan.
    pub fn fault_stats(&self) -> Option<FaultStats> {
        self.fabric.fault_stats()
    }

    /// Mirror the fabric's fault counters into the registry **now**
    /// (the housekeeper also does this on its sweep tick; call before
    /// reading `counters_snapshot` to avoid a stale tail).
    pub fn sync_fault_counters(&self) {
        if let Some(fc) = &self.fault_counters {
            if let Some(s) = self.fabric.fault_stats() {
                fc.mirror(s);
            }
        }
    }

    /// The set's tracer, when the config enables tracing (`trace`
    /// block). Drained by the housekeeper; callers can also pull kept
    /// traces on demand through [`crate::trace::Tracer::completed`].
    pub fn tracer(&self) -> Option<&Arc<crate::trace::Tracer>> {
        self.tracer.as_ref()
    }

    /// Set-level trace hook for request-scoped events recorded outside
    /// any instance (the federation router's `Routed` events).
    pub fn trace_hook(&self) -> Option<&crate::trace::TraceHook> {
        self.trace_hook.as_ref()
    }

    /// Export the proxy's fast-reject state (federation routing input).
    pub fn admission_snapshot(&self, app: AppId) -> crate::proxy::AdmissionSnapshot {
        self.proxy.admission_snapshot(app)
    }

    /// Size of the idle pool right now.
    pub fn idle_count(&self) -> usize {
        self.nm.idle_pool().len()
    }

    /// Per-stage windowed utilization for `app` (NM view, §8.2).
    pub fn stage_utilizations(&self, app: AppId) -> Vec<f64> {
        let Some(cfg) = self.nm.app_config(app) else {
            return Vec::new();
        };
        (0..cfg.stages.len() as u32)
            .map(|stage| self.nm.stage_utilization(StageKey { app, stage }))
            .collect()
    }

    /// Highest per-stage utilization for `app` — the set's scale-up
    /// pressure signal.
    pub fn max_stage_utilization(&self, app: AppId) -> f64 {
        self.stage_utilizations(app)
            .into_iter()
            .fold(0.0, f64::max)
    }

    /// Cross-set reclaim: spawn a fresh instance into this set's idle
    /// pool (capacity arriving from a donor set). The NM's next §8.2
    /// pass assigns it wherever pressure is highest.
    pub fn add_idle_instance(&mut self) -> NodeId {
        self.spawn_instance(self.ring)
    }

    /// Cross-set donate: retire one idle-pool instance and return its
    /// node id, or `None` when the pool is empty (assigned capacity is
    /// never donated). The instance is deregistered from the NM and its
    /// thread group is shut down.
    pub fn retire_idle_instance(&mut self) -> Option<NodeId> {
        let node = self.nm.release_idle()?;
        if let Some(idx) = self.instances.iter().position(|i| i.node() == node) {
            let inst = self.instances.swap_remove(idx);
            inst.shutdown();
        }
        self.crash_handles.lock().unwrap().retain(|(n, _)| *n != node);
        Some(node)
    }

    /// Crash injection: simulate the death of `node` (threads go
    /// dormant; heartbeats stop; the failure detector takes it from
    /// there). Returns `false` for unknown nodes.
    pub fn inject_crash(&self, node: NodeId) -> bool {
        let handles = self.crash_handles.lock().unwrap();
        match handles.iter().find(|(n, _)| *n == node) {
            Some((_, h)) => {
                h.kill();
                true
            }
            None => false,
        }
    }

    /// Crash injection by stage: kill the first live instance serving
    /// `key`. Returns the victim, if the stage had one.
    pub fn inject_crash_at_stage(&self, key: StageKey) -> Option<NodeId> {
        let serving = self.nm.stage_instances(key);
        let handles = self.crash_handles.lock().unwrap();
        let (node, h) = handles
            .iter()
            .find(|(n, h)| serving.contains(n) && !h.is_crashed())?;
        h.kill();
        Some(*node)
    }

    /// Run one NM rebalance pass (§8.2); the paper runs this on a timer.
    pub fn rebalance(&self) -> Option<crate::nm::RebalanceAction> {
        self.nm.rebalance()
    }

    /// Aggregate instance stats.
    pub fn instance_stats(&self) -> Vec<(NodeId, crate::workflow::InstanceStats, f64)> {
        self.instances
            .iter()
            .map(|i| (i.node(), i.stats(), i.utilization()))
            .collect()
    }

    /// Shut down the housekeeper and all instances.
    pub fn shutdown(mut self) {
        self.hk_stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = self.housekeeper.take() {
            let _ = h.join();
        }
        // Final mirror after the housekeeper is gone: the registry's
        // fault rows reflect everything the fabric counted.
        self.sync_fault_counters();
        for i in self.instances {
            i.shutdown();
        }
    }
}

impl Gateway for WorkflowSet {
    /// Submit through the set's proxy, applying the options' retry policy
    /// on fast-reject.
    fn submit_with(
        &self,
        app: AppId,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        crate::client::retry_rounds(&opts, payload, |payload| {
            self.submit_once(app, payload, &opts)
                .map(|uid| self.handle_for(uid, 0, &opts))
        })
    }
}

/// Several regionally-autonomous sets + the client-side retry policy.
pub struct MultiSet {
    pub sets: Vec<WorkflowSet>,
    rng: std::sync::Mutex<Rng>,
}

impl MultiSet {
    pub fn new(sets: Vec<WorkflowSet>, seed: u64) -> Self {
        Self { sets, rng: std::sync::Mutex::new(Rng::new(seed)) }
    }
}

impl Gateway for MultiSet {
    /// Client submission: random set first (§3: "incoming requests are
    /// distributed randomly across these sets"), then fall through on
    /// fast-reject. The payload moves from attempt to attempt — **no
    /// clone is ever taken**; a rejecting proxy hands it back. The retry
    /// policy re-walks the whole ring with backoff between rounds.
    fn submit_with(
        &self,
        app: AppId,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError> {
        let n = self.sets.len();
        if n == 0 {
            return Err(SubmitError::NoCapacity);
        }
        crate::client::retry_rounds(&opts, payload, |mut payload| {
            let start = self.rng.lock().unwrap().below(n as u64) as usize;
            let mut best: Option<Duration> = None;
            for k in 0..n {
                let idx = (start + k) % n;
                match self.sets[idx].submit_once(app, payload, &opts) {
                    Ok(uid) => return Ok(self.sets[idx].handle_for(uid, idx, &opts)),
                    Err((e, p)) => {
                        payload = p;
                        best = e.fold_hint(best);
                    }
                }
            }
            Err((SubmitError::from_hint(best), payload))
        })
    }
}

/// Build the standard executor pool for a config: PJRT executors when
/// `runtime` is provided (and the stage uses an artifact), simulated
/// executors otherwise.
pub fn build_pool(config: &ClusterConfig, runtime: Option<Arc<PjrtRuntime>>) -> ExecutorPool {
    let mut pool = ExecutorPool::new();
    for app in &config.apps {
        for s in &app.stages {
            let exec = match (&s.exec, &runtime) {
                (ExecModel::Artifact(name), Some(rt)) => StageExecutor::Pjrt {
                    runtime: rt.clone(),
                    stage: name.clone(),
                },
                (ExecModel::Artifact(_), None) => StageExecutor::Simulated {
                    busy: Duration::from_micros((s.exec_ms * 1000.0) as u64),
                },
                (ExecModel::Simulated { ms }, _) => StageExecutor::Simulated {
                    busy: Duration::from_micros((ms * 1000.0) as u64),
                },
            };
            pool.insert(s.name.clone(), exec);
        }
    }
    pool
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::WaitOutcome;
    use crate::config::FabricKind;
    use crate::workflow::EchoLogic;

    fn sim_config() -> ClusterConfig {
        let mut cfg = ClusterConfig::i2v_default();
        cfg.fabric = FabricKind::Ideal;
        // Shrink stage times so tests are fast; simulated executors.
        for s in cfg.apps[0].stages.iter_mut() {
            s.exec = ExecModel::Simulated { ms: 1.0 };
            s.exec_ms = 1.0;
        }
        cfg.idle_pool = 1;
        cfg
    }

    #[test]
    fn end_to_end_echo_request() {
        let cfg = sim_config();
        let pool = build_pool(&cfg, None);
        let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
        let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
        std::thread::sleep(Duration::from_millis(80)); // assignments settle

        let handle = set
            .submit(AppId(1), Payload::Bytes(b"request".to_vec()))
            .expect("must admit");
        let WaitOutcome::Done(result) = handle.wait(Duration::from_secs(10)) else {
            panic!("pipeline must produce a result")
        };
        // EchoLogic passes the payload through all four stages into the DB.
        let msg = crate::transport::WorkflowMessage::decode(&result).unwrap();
        assert_eq!(msg.payload, Payload::Bytes(b"request".to_vec()));
        assert_eq!(msg.header.uid, handle.uid());
        assert_eq!(handle.status(), crate::client::RequestStatus::Done);
        // Per-priority accounting reached the set's registry.
        assert_eq!(set.metrics().counter("accepted.standard").get(), 1);
        set.shutdown();
    }

    #[test]
    fn batching_set_serves_requests_end_to_end() {
        use crate::client::{SubmitOptions, WaitOutcome};
        let mut cfg = sim_config();
        cfg.batch = Some(crate::config::BatchSettings {
            max_batch: 4,
            max_wait_us: 5_000,
            adaptive: true,
            interactive_bypass: true,
            max_starvation_ms: 100,
        });
        // Diffusion defaults to CM; run it IM here so every stage can
        // coalesce.
        cfg.apps[0].stages[2].mode = crate::config::SchedMode::Individual;
        let pool = build_pool(&cfg, None);
        let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
        std::thread::sleep(Duration::from_millis(80));
        let mut handles = Vec::new();
        for i in 0..8u8 {
            handles.push(
                set.submit_with(
                    AppId(1),
                    Payload::Bytes(vec![i; 16]),
                    SubmitOptions::batch(),
                )
                .expect("must admit"),
            );
        }
        for h in handles {
            assert!(
                matches!(h.wait(Duration::from_secs(10)), WaitOutcome::Done(_)),
                "batched pipeline must still complete every request"
            );
        }
        assert!(
            set.metrics().counter("batches_executed").get() >= 1,
            "the burst must have formed at least one micro-batch"
        );
        set.shutdown();
    }

    #[test]
    fn cache_enabled_set_serves_repeat_submission_at_admission() {
        let mut cfg = sim_config();
        cfg.cache = Some(crate::config::CacheSettings::default());
        let pool = build_pool(&cfg, None);
        let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
        let set = WorkflowSet::build(cfg, counts, Arc::new(EchoLogic), pool);
        std::thread::sleep(Duration::from_millis(80));

        let payload = Payload::Bytes(b"same request twice".to_vec());
        let h1 = set.submit(AppId(1), payload.clone()).expect("must admit");
        let WaitOutcome::Done(r1) = h1.wait(Duration::from_secs(10)) else {
            panic!("first (uncached) pass must complete")
        };
        // Identical resubmission: the proxy serves it from the workflow
        // tier — no new pipeline traversal, same payload bytes.
        let h2 = set.submit(AppId(1), payload).expect("must admit");
        let WaitOutcome::Done(r2) = h2.wait(Duration::from_secs(10)) else {
            panic!("cache hit must produce a result")
        };
        let m1 = crate::transport::WorkflowMessage::decode(&r1).unwrap();
        let m2 = crate::transport::WorkflowMessage::decode(&r2).unwrap();
        assert_eq!(m1.payload, m2.payload, "hit is byte-identical in payload");
        assert_eq!(m2.header.uid, h2.uid());
        assert!(
            set.metrics().counter("cache_hits.__workflow__").get() >= 1,
            "second submission must hit the workflow tier"
        );
        set.shutdown();
    }

    #[test]
    fn housekeeper_auto_rebalances_and_purges() {
        let mut cfg = sim_config();
        cfg.nm.auto_rebalance = true;
        cfg.nm.heartbeat_ms = 10; // sweep every ~50 ms
        cfg.db.ttl_ms = 30;
        let pool = build_pool(&cfg, None);
        let set = WorkflowSet::build(cfg, vec![vec![1, 1, 1, 1]], Arc::new(EchoLogic), pool);
        std::thread::sleep(Duration::from_millis(60));

        // Force a hot stage; the housekeeping timer must act within a few
        // sweeps without any manual rebalance() call.
        use crate::workflow::ControlPlane;
        let diffusion = crate::nm::StageKey { app: AppId(1), stage: 2 };
        let node = set.nm.stage_instances(diffusion)[0];
        // Keep reporting high utilization (instances also self-report 0,
        // so re-assert in a loop until the move happens).
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while set.nm.stage_instances(diffusion).len() < 2
            && std::time::Instant::now() < deadline
        {
            set.nm.report_utilization(node, 0.99);
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(
            set.nm.stage_instances(diffusion).len() >= 2,
            "housekeeper must scale the hot stage"
        );
        assert!(set.auto_rebalances.load(std::sync::atomic::Ordering::Relaxed) >= 1);

        // TTL purge: a stored result vanishes without any fetch.
        set.dbs[0].put(crate::util::Uid::fresh(NodeId(9)), vec![1, 2, 3]);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while set.dbs[0].len() > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(set.dbs[0].len(), 0, "housekeeper must purge expired results");

        // Tracker sweep: a lost request's entry ages out with the TTL.
        set.tracker().register(
            crate::util::Uid::fresh(NodeId(8)),
            crate::client::Priority::Standard,
            None,
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !set.tracker().is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(set.tracker().is_empty(), "housekeeper must sweep stale tracker entries");
        set.shutdown();
    }

    #[test]
    fn heartbeats_keep_primary_alive() {
        let cfg = sim_config();
        let pool = build_pool(&cfg, None);
        let set = WorkflowSet::build(cfg, vec![vec![1, 0, 0, 0]], Arc::new(EchoLogic), pool);
        // Past the heartbeat timeout: without the housekeeper's beats the
        // primary would be considered lost.
        std::thread::sleep(Duration::from_millis(600));
        assert!(!set.nm_cluster.primary_lost(), "housekeeper heartbeats missing");
        set.shutdown();
    }

    #[test]
    fn multiset_retries_on_reject() {
        // Set 0 has no entrance instances => always rejects; set 1 works.
        let cfg = sim_config();
        let pool = build_pool(&cfg, None);
        let set0 = WorkflowSet::build(
            cfg.clone(),
            vec![vec![0, 0, 0, 0]],
            Arc::new(EchoLogic),
            pool.clone(),
        );
        let set1 = WorkflowSet::build(
            cfg.clone(),
            vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)],
            Arc::new(EchoLogic),
            pool,
        );
        std::thread::sleep(Duration::from_millis(80));
        let multi = MultiSet::new(vec![set0, set1], 7);
        let handle = multi
            .submit(AppId(1), Payload::Bytes(vec![1]))
            .expect("second set must accept");
        assert_eq!(handle.set(), 1);
        assert!(matches!(
            handle.wait(Duration::from_secs(10)),
            WaitOutcome::Done(_)
        ));
        for s in multi.sets {
            s.shutdown();
        }
    }

    #[test]
    fn multiset_with_no_capacity_anywhere_reports_it() {
        let cfg = sim_config();
        let pool = build_pool(&cfg, None);
        let dead = WorkflowSet::build(
            cfg.clone(),
            vec![vec![0, 0, 0, 0]],
            Arc::new(EchoLogic),
            pool,
        );
        std::thread::sleep(Duration::from_millis(40));
        let multi = MultiSet::new(vec![dead], 5);
        assert_eq!(
            multi.submit(AppId(1), Payload::Bytes(vec![2])).unwrap_err(),
            SubmitError::NoCapacity
        );
        for s in multi.sets {
            s.shutdown();
        }
    }
}
