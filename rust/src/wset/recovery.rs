//! The worker-failure recovery sweep: detect → repair → replay.
//!
//! Run by the set's housekeeping timer (when `nm.instance_timeout_ms`
//! enables the failure detector), one [`RecoverySweep::sweep`] per tick:
//!
//! 1. **Detect** — [`NodeManager::detect_failures`] evicts every
//!    instance whose heartbeat (piggybacked on its §8.2 utilization
//!    report) went silent for longer than the timeout, bumping upstream
//!    assignment versions so `ResultDeliver`s drop the dead hop and
//!    prune its ring producer.
//! 2. **Repair** — [`NodeManager::promote_replacement`] refills the
//!    orphaned stage through the existing §8.2 machinery: idle pool
//!    first, then a donor stage that can spare an instance.
//! 3. **Replay** — every in-flight UID whose last recorded location is
//!    the dead instance's ring is re-sent from its last completed
//!    stage's checkpoint ([`MemDb::checkpoint`]) to the stage's
//!    surviving / promoted instances. Replays consume the request's
//!    recovery budget (the submit `RetryPolicy`); when it runs out — or
//!    no checkpoint / no capacity remains — a `Failed` tombstone is
//!    published so the client observes a terminal state instead of a
//!    hang. First-writer-wins in the database layer guarantees a replay
//!    and a late original result never double-publish.

use crate::client::{ReplayVerdict, RequestTracker};
use crate::db::{DbClient, EntryKind, MemDb};
use crate::metrics::{Counter, Histogram, Registry};
use crate::nm::NodeManager;
use crate::rdma::{Fabric, RegionId};
use crate::transport::{RdmaEndpoint, RdmaSender, WorkflowMessage};
use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::Arc;

/// One set's failure detector + repair + replay driver. Owned by the
/// housekeeping thread; keeps a ring-producer cache across sweeps.
pub struct RecoverySweep {
    nm: Arc<NodeManager>,
    tracker: Arc<RequestTracker>,
    dbs: Vec<Arc<MemDb>>,
    db: Arc<DbClient>,
    fabric: Fabric,
    clock: Arc<dyn Clock>,
    /// Heartbeat-silence threshold (ns).
    timeout_ns: u64,
    senders: HashMap<RegionId, RdmaSender>,
    /// Ring-path counters attached to every replay sender.
    ring_metrics: crate::transport::RingMetrics,
    /// Eager/rendezvous cutover for replay sends — replays must use the
    /// same data plane the original delivery did
    /// (`rdma.rendezvous_threshold_bytes`; 0 = eager only).
    rendezvous_threshold: usize,
    /// Recently evicted rings, revisited for one grace window: an
    /// upstream with a stale route (control poll ~5 ms) can deliver into
    /// a dead ring *after* the eviction sweep's replay snapshot; without
    /// a revisit those requests would strand forever.
    recent_dead: Vec<(RegionId, u64 /* last_seen_ns */, u64 /* evicted_at_ns */)>,
    /// Tracing hook (None = tracing off): each successful checkpoint
    /// replay records a `Replayed` event for the recovered request.
    trace: Option<crate::trace::TraceHook>,
    instances_failed: Arc<Counter>,
    instances_replaced: Arc<Counter>,
    requests_recovered: Arc<Counter>,
    /// Time from an instance's last heartbeat to each of its requests
    /// being replayed (ns) — the stranded time a client observed.
    recovery_latency: Arc<Histogram>,
}

impl RecoverySweep {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        nm: Arc<NodeManager>,
        tracker: Arc<RequestTracker>,
        dbs: Vec<Arc<MemDb>>,
        db: Arc<DbClient>,
        fabric: Fabric,
        clock: Arc<dyn Clock>,
        timeout_ns: u64,
        metrics: &Registry,
    ) -> Self {
        Self {
            nm,
            tracker,
            dbs,
            db,
            fabric,
            clock,
            timeout_ns,
            senders: HashMap::new(),
            ring_metrics: crate::transport::RingMetrics::from_registry(metrics),
            rendezvous_threshold: 0,
            recent_dead: Vec::new(),
            trace: None,
            instances_failed: metrics.counter("instances_failed"),
            instances_replaced: metrics.counter("instances_replaced"),
            requests_recovered: metrics.counter("requests_recovered"),
            recovery_latency: metrics.histogram("recovery_latency_ns"),
        }
    }

    /// Attach the set's tracing hook: successful replays record a
    /// `Replayed` event so kept traces show the recovery hop.
    pub fn set_trace(&mut self, trace: crate::trace::TraceHook) {
        self.trace = Some(trace);
    }

    /// Set the eager/rendezvous cutover on current and future replay
    /// senders.
    pub fn set_rendezvous_threshold(&mut self, bytes: usize) {
        self.rendezvous_threshold = bytes;
        for tx in self.senders.values_mut() {
            tx.set_rendezvous_threshold(bytes);
        }
    }

    /// One detect → repair → replay pass. Returns the number of dead
    /// instances handled (0 on the healthy fast path).
    pub fn sweep(&mut self) -> usize {
        // Revisit recently dead rings first: anything that raced into
        // them since the previous sweep still needs a replay (or a
        // terminal verdict). One detector-timeout of grace comfortably
        // covers the stale-route window.
        let now = self.clock.now_ns();
        let grace_ns = self.timeout_ns.max(1_000_000_000);
        self.recent_dead
            .retain(|(_, _, evicted_at)| now.saturating_sub(*evicted_at) <= grace_ns);
        let revisit = std::mem::take(&mut self.recent_dead);
        for (region, last_seen, evicted_at) in revisit {
            self.replay_stranded(region, last_seen);
            self.recent_dead.push((region, last_seen, evicted_at));
        }
        let failures = self.nm.detect_failures(self.timeout_ns);
        for f in &failures {
            self.instances_failed.inc();
            if let Some(role) = f.role {
                if self.nm.promote_replacement(role).is_some() {
                    self.instances_replaced.inc();
                }
            }
            if let Some(region) = f.region {
                self.replay_stranded(region, f.last_seen_ns);
                // The dead ring will never be drained again; keep it on
                // the revisit list for the grace window.
                self.senders.remove(&region);
                self.recent_dead.push((region, f.last_seen_ns, now));
            }
        }
        // Requests the data plane handed over directly (role changed
        // mid-queue during a donor steal, downstream ring refused): same
        // replay path, the instance itself is alive. Stranding time is
        // within one sweep period, so record latency from `now`.
        for uid in self.tracker.take_stranded() {
            self.replay_uid(uid, now);
        }
        // Prune producers whose ring no live instance owns any more —
        // healthy retirement (elastic donation / deregister) never
        // passes through detect_failures, and a retired ring must not
        // hold a producer forever (the same leak the set_routes fix
        // closed in ResultDeliver).
        if !self.senders.is_empty() {
            let live: std::collections::HashSet<RegionId> = self
                .nm
                .instances()
                .into_iter()
                .filter_map(|i| i.region)
                .collect();
            self.senders.retain(|rid, _| live.contains(rid));
        }
        failures.len()
    }

    /// Replay (or fail) every in-flight request stranded on `region`.
    fn replay_stranded(&mut self, region: RegionId, last_seen_ns: u64) {
        for uid in self.tracker.uids_at(region) {
            self.replay_uid(uid, last_seen_ns);
        }
    }

    /// Replay one request from its newest checkpoint, or publish its
    /// terminal `Failed` state when it cannot be replayed.
    fn replay_uid(&mut self, uid: Uid, last_seen_ns: u64) {
        // Consume any pending stranded flag: a UID reached via its dead
        // ring must not be replayed a second time by this sweep's
        // take_stranded() loop (double replay would burn budget and
        // dispatch duplicate work).
        self.tracker.unstrand(uid);
        // A terminal entry already exists on some replica (the crash
        // raced completion): the handle will consume it — replaying
        // would only burn budget, and first-writer-wins would suppress
        // the duplicate anyway.
        if self.dbs.iter().any(|db| db.peek(uid).is_some()) {
            return;
        }
        // Newest checkpoint across replicas (replicas may have diverged
        // if one missed a later stage's write — replaying a stale
        // earlier stage would re-execute completed work).
        let Some(ck) = self.db.checkpoint(uid) else {
            self.fail(uid);
            return;
        };
        let Ok(msg) = WorkflowMessage::decode(&ck.data) else {
            self.fail(uid);
            return;
        };
        let regions = self.nm.stage_regions(msg.header.app, ck.stage);
        if regions.is_empty() {
            // Repair found no replacement: the stage is gone.
            self.fail(uid);
            return;
        }
        match self.tracker.begin_replay(uid) {
            ReplayVerdict::Terminal => {}
            ReplayVerdict::Exhausted => self.publish_failed(uid),
            ReplayVerdict::Replay => {
                // Deterministic first pick by UID, then fall through the
                // stage's other live rings — one momentarily full ring
                // (replayed backlog draining) must not fail a request
                // a sibling instance could accept.
                let start = (uid.0 % regions.len() as u128) as usize;
                let mut sent = false;
                for k in 0..regions.len() {
                    let target = regions[(start + k) % regions.len()];
                    let tx = match self.senders.entry(target) {
                        std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                        std::collections::hash_map::Entry::Vacant(e) => {
                            // The replacement ring may itself have died
                            // since repair picked it: skip to the next
                            // sibling instead of crashing the sweeper.
                            let Ok(mut tx) = RdmaEndpoint::sender_for(&self.fabric, target)
                            else {
                                continue;
                            };
                            tx.set_metrics(self.ring_metrics.clone());
                            tx.set_rendezvous_threshold(self.rendezvous_threshold);
                            e.insert(tx)
                        }
                    };
                    if tx.send(&msg) {
                        self.tracker.note_location(uid, target);
                        self.requests_recovered.inc();
                        self.recovery_latency
                            .record(self.clock.now_ns().saturating_sub(last_seen_ns));
                        if let Some(t) = &self.trace {
                            t.record(
                                uid,
                                Some(ck.stage),
                                crate::trace::EventKind::Replayed,
                            );
                        }
                        sent = true;
                        break;
                    }
                }
                if !sent {
                    // Every live ring refused the write (sustained
                    // backpressure): give up rather than hang the
                    // client.
                    self.fail(uid);
                }
            }
        }
    }

    /// Declare `uid` unrecoverable and publish its terminal state.
    fn fail(&self, uid: Uid) {
        if self.tracker.mark_failed(uid) {
            self.publish_failed(uid);
        }
    }

    /// Publish the `Failed` tombstone to every replica (first-writer-
    /// wins: a result that sneaked in concurrently is preserved).
    fn publish_failed(&self, uid: Uid) {
        for db in &self.dbs {
            db.put_tombstone(uid, EntryKind::Failed);
        }
    }
}
