//! # OnePiece — distributed AIGC inference with (simulated) one-sided RDMA
//!
//! Reproduction of *"OnePiece: A Large-Scale Distributed Inference System
//! with RDMA for Complex AI-Generated Content (AIGC) Workflows"*.
//!
//! The system decomposes multi-stage AIGC pipelines (text-encode →
//! VAE-encode → diffusion → VAE-decode) into microservices grouped into
//! regionally-autonomous **Workflow Sets**, connected by one-sided RDMA.
//! This crate is the L3 coordinator of the three-layer stack:
//!
//! - **L3 (this crate)**: workflow sets, proxies with fast-reject admission
//!   control, workflow instances (TaskManager / RequestScheduler /
//!   TaskWorkers / ResultDeliver), the NodeManager with Paxos primary
//!   election, the memory-centric database layer, the simulated RDMA
//!   fabric, the paper's deadlock-free multi-producer **double-ring
//!   buffer** ([`ringbuf`]), the cross-set [`federation`] layer
//!   (global load-aware routing, spill, and elastic instance donation
//!   over N Workflow Sets), the content-addressed artifact [`cache`]
//!   (stage-skip on repeat inputs, warm tier served by one-sided READs),
//!   the unified [`client`] gateway API (typed request handles with
//!   priorities, deadlines, and cancellation across every tier), and the
//!   off-by-default per-request tracing layer ([`trace`]: flight
//!   recorders + drain-time stitching into queue/execute/transit
//!   breakdowns and critical paths). The crate also lints itself: [`lint`] is an in-crate static-analysis
//!   pass (`onepiece lint`) enforcing the concurrency/RDMA-protocol
//!   invariants, with a debug-build lock-order witness in
//!   [`lint::runtime`].
//! - **L2/L1 (build-time python)**: JAX stage models calling Pallas
//!   kernels, AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **Runtime bridge**: [`runtime`] loads the HLO artifacts through the
//!   PJRT CPU client (`xla` crate, behind the `pjrt` feature) — python
//!   never runs on the request path.
//!
//! See `DESIGN.md` for the full system inventory and the request
//! lifecycle walkthrough, and `EXPERIMENTS.md` for the experiment index
//! mapping every bench/example to the paper claim it reproduces.

pub mod batch;
pub mod bench;
pub mod cache;
pub mod client;
pub mod config;
pub mod db;
pub mod federation;
pub mod lint;
pub mod metrics;
pub mod nm;
pub mod paxos;
pub mod pipeline;
pub mod proxy;
pub mod rdma;
pub mod ringbuf;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod transport;
pub mod util;
pub mod workflow;
pub mod wset;
