//! The batch assembler: turns single-message queue fetches into
//! micro-batches.

use super::{AdaptiveWindow, BatchPolicy};
use crate::client::RequestTracker;
use crate::transport::WorkflowMessage;
use crate::workflow::SchedQueue;
use std::time::{Duration, Instant};

/// One assembled micro-batch: ≥ 1 compatible messages (same app, same
/// stage, same priority band) a worker executes in a single
/// `AppLogic::execute_batch` invocation.
#[derive(Debug)]
pub struct MicroBatch {
    pub members: Vec<WorkflowMessage>,
    /// How long formation waited after the first member (0 for bypass).
    pub wait: Duration,
    /// The policy bypassed batching for this request (Interactive-class
    /// bypass or the worker-0 fast lane) — accounted separately from
    /// formed batches.
    pub bypassed: bool,
}

impl MicroBatch {
    /// A batch of one, formed without waiting.
    pub fn single(msg: WorkflowMessage, bypassed: bool) -> Self {
        Self { members: vec![msg], wait: Duration::ZERO, bypassed }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

/// Per-instance batch former. Holds one [`AdaptiveWindow`] **per
/// priority band** — batches only form within a band, and per-class
/// `max_wait` overrides would otherwise clobber each other's window and
/// cap state through a shared controller. The policy arrives per call
/// because reassignment can change it at any control poll.
#[derive(Default)]
pub struct BatchAssembler {
    adaptive: [AdaptiveWindow; 3],
}

impl BatchAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed a §4.2 utilization sample to every band's controller (an
    /// idle instance collapses all windows for latency).
    pub fn observe_utilization(&self, util: f64) {
        for w in &self.adaptive {
            w.observe_utilization(util);
        }
    }

    /// The widest effective window across bands, µs — what the control
    /// thread exports to the NodeManager (0 until the first batch).
    pub fn window_us(&self) -> u64 {
        self.adaptive.iter().map(AdaptiveWindow::window_us).max().unwrap_or(0)
    }

    /// Grow `first` (already fetched from the queue) into a micro-batch
    /// by draining compatible messages — same app, same stage, same
    /// priority band — until one of the closing conditions fires:
    ///
    /// - **size**: `max_batch` for the first member's SLO class;
    /// - **deadline of the oldest member**: the batch never waits the
    ///   first member past its SLO deadline to fatten itself;
    /// - **window expiry**: the (adaptive) formation window runs out.
    ///
    /// `fast_lane` callers (worker 0 of a multi-worker stage) always get
    /// a bypass batch of one, so one worker stays immediately available
    /// for bypassing Interactive arrivals.
    pub fn assemble(
        &self,
        first: WorkflowMessage,
        policy: &BatchPolicy,
        queue: &SchedQueue,
        tracker: &RequestTracker,
        fast_lane: bool,
    ) -> MicroBatch {
        let prio = tracker.priority_of(first.header.uid);
        let cap = policy.max_batch_for(prio);
        if fast_lane || cap <= 1 {
            return MicroBatch::single(first, true);
        }
        let wait_cap = policy.max_wait_for(prio);
        let band = prio.index();
        let window = if policy.adaptive {
            self.adaptive[band].current(wait_cap)
        } else {
            wait_cap
        };
        let start = Instant::now();
        let mut close = start + window;
        // Deadline-of-oldest-member: `first` is the oldest (FIFO bands),
        // so its remaining SLO budget caps the wait.
        if let Some(left) = tracker.time_left(first.header.uid) {
            close = close.min(start + left);
        }
        let (app, stage) = (first.header.app, first.header.stage);
        let mut members = vec![first];
        while members.len() < cap {
            match queue.fetch_matching(band, app, stage, close) {
                Some(m) => members.push(m),
                // Window expired / queue closed / mode changed.
                None => break,
            }
        }
        let wait = start.elapsed();
        if policy.adaptive {
            // Backlog = messages this batch *could* have taken (same
            // band/app/stage) — unrelated or bypass-class queue depth
            // must not hold the window open for a class with nothing to
            // coalesce.
            self.adaptive[band].observe(
                members.len(),
                cap,
                queue.depth_matching(band, app, stage),
                wait_cap,
            );
        }
        MicroBatch { members, wait, bypassed: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Priority;
    use crate::config::{BatchSettings, SchedMode};
    use crate::metrics::Registry;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, SystemClock, Uid};
    use std::sync::Arc;

    fn msg(i: u32, app: u32, stage: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 0,
                app: AppId(app),
                stage: StageId(stage),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8]),
        }
    }

    fn setup() -> (Arc<SchedQueue>, Arc<RequestTracker>, BatchPolicy) {
        let queue = SchedQueue::new(SchedMode::Individual, 2);
        let clock: Arc<dyn crate::util::Clock> = Arc::new(SystemClock);
        let tracker = Arc::new(RequestTracker::new(clock, Registry::new()));
        let policy = BatchPolicy::from_settings(&BatchSettings {
            max_batch: 4,
            max_wait_us: 20_000, // 20 ms window: plenty for queued members
            adaptive: false,
            interactive_bypass: true,
            max_starvation_ms: 0,
        });
        (queue, tracker, policy)
    }

    fn reg(tracker: &RequestTracker, i: u32, prio: Priority) {
        tracker.register(Uid(i as u128), prio, None);
    }

    #[test]
    fn closes_on_size_with_compatible_members() {
        let (queue, tracker, policy) = setup();
        let asm = BatchAssembler::new();
        for i in 0..6 {
            reg(&tracker, i, Priority::Batch);
            queue.dispatch(msg(i, 1, 0), Priority::Batch);
        }
        let first = queue.fetch(0, Duration::from_millis(10)).unwrap();
        let t0 = Instant::now();
        let b = asm.assemble(first, &policy, &queue, &tracker, false);
        assert_eq!(b.len(), 4, "closes on max_batch");
        assert!(!b.bypassed);
        assert!(
            t0.elapsed() < Duration::from_millis(15),
            "a full queue must not wait out the window"
        );
        assert_eq!(queue.depth(), 2, "surplus stays queued");
        let uids: Vec<u128> = b.members.iter().map(|m| m.header.uid.0).collect();
        assert_eq!(uids, vec![0, 1, 2, 3], "FIFO order preserved");
    }

    #[test]
    fn closes_on_window_expiry_when_queue_runs_dry() {
        let (queue, tracker, policy) = setup();
        let asm = BatchAssembler::new();
        for i in 0..2 {
            reg(&tracker, i, Priority::Standard);
            queue.dispatch(msg(i, 1, 0), Priority::Standard);
        }
        let first = queue.fetch(0, Duration::from_millis(10)).unwrap();
        let t0 = Instant::now();
        let b = asm.assemble(first, &policy, &queue, &tracker, false);
        assert_eq!(b.len(), 2, "takes what arrived, then times out");
        assert!(t0.elapsed() >= Duration::from_millis(19), "waited the window out");
    }

    #[test]
    fn interactive_bypasses_and_fast_lane_bypasses() {
        let (queue, tracker, policy) = setup();
        let asm = BatchAssembler::new();
        reg(&tracker, 7, Priority::Interactive);
        let b = asm.assemble(msg(7, 1, 0), &policy, &queue, &tracker, false);
        assert!(b.bypassed);
        assert_eq!(b.len(), 1);
        // Fast lane: even a Batch-class request stays single on worker 0.
        reg(&tracker, 8, Priority::Batch);
        let b = asm.assemble(msg(8, 1, 0), &policy, &queue, &tracker, true);
        assert!(b.bypassed);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn incompatible_messages_stay_queued() {
        let (queue, tracker, policy) = setup();
        let asm = BatchAssembler::new();
        for (i, (app, stage, prio)) in [
            (1, 0, Priority::Batch),     // compatible
            (2, 0, Priority::Batch),     // other app
            (1, 1, Priority::Batch),     // other stage
            (1, 0, Priority::Standard),  // other band
        ]
        .into_iter()
        .enumerate()
        {
            let i = i as u32;
            reg(&tracker, i, prio);
            queue.dispatch(msg(i, app, stage), prio);
        }
        reg(&tracker, 9, Priority::Batch);
        let b = asm.assemble(msg(9, 1, 0), &policy, &queue, &tracker, false);
        let uids: Vec<u128> = b.members.iter().map(|m| m.header.uid.0).collect();
        assert_eq!(uids, vec![9, 0], "only the same-app/stage/band member joins");
        assert_eq!(queue.depth(), 3, "incompatible messages remain for other workers");
    }

    #[test]
    fn oldest_member_deadline_caps_the_window() {
        let (queue, tracker, policy) = setup();
        let asm = BatchAssembler::new();
        // 5 ms of SLO budget left against a 20 ms window: formation must
        // close early instead of holding the request past its deadline.
        tracker.register(Uid(1), Priority::Batch, Some(Duration::from_millis(5)));
        let t0 = Instant::now();
        let b = asm.assemble(msg(1, 1, 0), &policy, &queue, &tracker, false);
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(15),
            "deadline-of-oldest must beat window expiry ({:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn incompatible_backlog_does_not_hold_the_window_open() {
        let (queue, tracker, mut policy) = setup();
        policy.adaptive = true;
        let asm = BatchAssembler::new();
        // Unrelated bypass-class backlog sits in band 0.
        for i in 0..6 {
            reg(&tracker, i, Priority::Interactive);
            queue.dispatch(msg(i, 1, 0), Priority::Interactive);
        }
        // A lone Standard request closes under-filled: with whole-queue
        // depth as the backlog signal the window would ratchet toward
        // the cap; the compatible-only signal shrinks it instead.
        reg(&tracker, 9, Priority::Standard);
        let cap_us = policy.max_wait_for(Priority::Standard).as_micros() as u64;
        let b = asm.assemble(msg(9, 1, 0), &policy, &queue, &tracker, false);
        assert_eq!(b.len(), 1);
        assert!(
            asm.window_us() < cap_us,
            "unrelated backlog must not count as coalescing demand"
        );
    }

    #[test]
    fn adaptive_policy_feeds_the_controller() {
        let (queue, tracker, mut policy) = setup();
        policy.adaptive = true;
        let asm = BatchAssembler::new();
        for i in 0..8 {
            reg(&tracker, i, Priority::Batch);
            queue.dispatch(msg(i, 1, 0), Priority::Batch);
        }
        let first = queue.fetch(0, Duration::from_millis(10)).unwrap();
        let b = asm.assemble(first, &policy, &queue, &tracker, false);
        assert_eq!(b.len(), 4);
        // Full batch + backlog: the controller must have seen demand and
        // kept the window open (it starts at the cap).
        assert_eq!(asm.window_us(), 20_000);
    }
}
