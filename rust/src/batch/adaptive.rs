//! The adaptive batch-window controller.
//!
//! Static windows force a bad trade: sized for peak they tax latency at
//! low load, sized for latency they starve batches under backlog. Batch
//! sizing must react to load rather than stay a static knob, so the
//! effective window here moves between ~0 and the policy's `max_wait`
//! from two signals the instance already has:
//!
//! - **fill + backlog** (per formed batch): a batch that filled to
//!   `max_batch`, or left messages queued behind it, means arrivals
//!   outpace service — grow the window toward `max_wait` so batches
//!   fatten. A batch that closed under half-full means the window is
//!   buying latency without buying amortization — shrink it.
//! - **utilization** (per §4.2 report): a mostly-idle instance has no
//!   throughput problem to solve — shrink toward immediate dispatch so
//!   light traffic keeps single-request latency.
//!
//! The current window is exported to the NodeManager with the
//! utilization heartbeat so the §8.2 allocator can tell "stage is slow"
//! from "stage is coalescing on purpose".

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Utilization under which the controller treats the instance as idle
/// and collapses the window for latency.
const IDLE_UTIL: f64 = 0.3;

/// Shrink floor: never adapt below `max_wait / SHRINK_DENOM` (a window
/// of exactly zero could never re-grow from fill observations alone
/// because no batch would ever form).
const SHRINK_DENOM: u64 = 16;

/// Lock-free adaptive window shared by an instance's workers (who form
/// batches) and its control thread (who feeds utilization and exports
/// the value).
pub struct AdaptiveWindow {
    /// Current effective window, µs. `u64::MAX` = unset (first use
    /// starts from the policy cap).
    window_us: AtomicU64,
    /// Last policy cap seen, µs (the shrink floor derives from it).
    cap_us: AtomicU64,
}

impl Default for AdaptiveWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl AdaptiveWindow {
    pub fn new() -> Self {
        Self {
            window_us: AtomicU64::new(u64::MAX),
            cap_us: AtomicU64::new(0),
        }
    }

    fn floor_us(cap_us: u64) -> u64 {
        (cap_us / SHRINK_DENOM).max(1)
    }

    /// Effective window for the next batch under `cap` (the policy's
    /// per-class `max_wait`). Also remembers the cap for the
    /// utilization-driven shrink path.
    pub fn current(&self, cap: Duration) -> Duration {
        let cap_us = cap.as_micros() as u64;
        self.cap_us.store(cap_us, Ordering::Relaxed);
        Duration::from_micros(self.window_us.load(Ordering::Relaxed).min(cap_us))
    }

    /// Feed one formed batch: `filled` members out of `max_batch`
    /// possible, with `backlog` messages still queued when it closed.
    pub fn observe(&self, filled: usize, max_batch: usize, backlog: usize, cap: Duration) {
        let cap_us = cap.as_micros() as u64;
        if cap_us == 0 {
            return;
        }
        let cur = self.window_us.load(Ordering::Relaxed).min(cap_us);
        let next = if filled >= max_batch || backlog > 0 {
            // Demand: arrivals outpace service — open the window toward
            // the cap so batches reach max_batch.
            (cur.saturating_mul(3) / 2).max(cur + 1).min(cap_us)
        } else if filled <= max_batch / 2 {
            // The window closed under half-full: it is buying latency,
            // not amortization.
            (cur / 2).max(Self::floor_us(cap_us))
        } else {
            cur
        };
        self.window_us.store(next, Ordering::Relaxed);
    }

    /// Feed a §4.2 utilization sample (the instance control thread calls
    /// this each report period): an idle instance collapses its window.
    pub fn observe_utilization(&self, util: f64) {
        if util >= IDLE_UTIL {
            return;
        }
        let cap_us = self.cap_us.load(Ordering::Relaxed);
        if cap_us == 0 {
            return;
        }
        let cur = self.window_us.load(Ordering::Relaxed).min(cap_us);
        self.window_us
            .store((cur / 2).max(Self::floor_us(cap_us)), Ordering::Relaxed);
    }

    /// Current window in µs — what the control thread exports to the
    /// NodeManager (`0` until the first [`AdaptiveWindow::current`]).
    pub fn window_us(&self) -> u64 {
        let cap = self.cap_us.load(Ordering::Relaxed);
        self.window_us.load(Ordering::Relaxed).min(cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAP: Duration = Duration::from_micros(1_600);

    #[test]
    fn starts_at_the_policy_cap() {
        let w = AdaptiveWindow::new();
        assert_eq!(w.current(CAP), CAP);
        assert_eq!(w.window_us(), 1_600);
    }

    #[test]
    fn backlog_grows_and_low_fill_shrinks() {
        let w = AdaptiveWindow::new();
        let _ = w.current(CAP);
        // Half-empty batches: shrink toward the floor…
        for _ in 0..10 {
            w.observe(1, 8, 0, CAP);
        }
        assert_eq!(w.window_us(), 100, "floor = cap/16");
        // …then sustained backlog re-opens the window up to the cap.
        for _ in 0..12 {
            w.observe(8, 8, 3, CAP);
        }
        assert_eq!(w.window_us(), 1_600);
        // Mid-fill without backlog holds steady.
        let before = w.window_us();
        w.observe(6, 8, 0, CAP);
        assert_eq!(w.window_us(), before);
    }

    #[test]
    fn low_utilization_collapses_window() {
        let w = AdaptiveWindow::new();
        let _ = w.current(CAP);
        w.observe_utilization(0.05);
        assert_eq!(w.window_us(), 800);
        // Busy instances keep their window.
        w.observe_utilization(0.9);
        assert_eq!(w.window_us(), 800);
    }

    #[test]
    fn zero_cap_is_inert() {
        let w = AdaptiveWindow::new();
        assert_eq!(w.current(Duration::ZERO), Duration::ZERO);
        w.observe(8, 8, 9, Duration::ZERO);
        w.observe_utilization(0.0);
        assert_eq!(w.window_us(), 0);
    }
}
