//! Per-stage batching policy: size/window caps with per-priority
//! overrides.

use crate::client::Priority;
use crate::config::BatchSettings;
use std::time::Duration;

/// Override of the batching knobs for one SLO class. `None` fields
/// inherit the stage-wide value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassPolicy {
    pub max_batch: Option<usize>,
    pub max_wait: Option<Duration>,
}

/// Resolved per-stage batching policy, carried inside the
/// [`crate::workflow::StageRole`] an instance receives from the
/// NodeManager. Built from the config's [`BatchSettings`].
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPolicy {
    /// Stage-wide member cap per micro-batch.
    pub max_batch: usize,
    /// Stage-wide formation-window cap (the adaptive controller shrinks
    /// the effective window below this).
    pub max_wait: Duration,
    /// Let [`crate::batch::AdaptiveWindow`] resize the window.
    pub adaptive: bool,
    /// Per-priority overrides, indexed by [`Priority::index`]. The
    /// default derived from `interactive_bypass` pins Interactive to
    /// `max_batch = 1, max_wait = 0` — a bypassing request is executed
    /// the moment a worker fetches it.
    pub per_priority: [ClassPolicy; 3],
}

impl BatchPolicy {
    /// Resolve a config `batch` block into a policy.
    pub fn from_settings(s: &BatchSettings) -> Self {
        let mut per_priority = [ClassPolicy::default(); 3];
        if s.interactive_bypass {
            per_priority[Priority::Interactive.index()] = ClassPolicy {
                max_batch: Some(1),
                max_wait: Some(Duration::ZERO),
            };
        }
        Self {
            max_batch: s.max_batch.max(1),
            max_wait: Duration::from_micros(s.max_wait_us),
            adaptive: s.adaptive,
            per_priority,
        }
    }

    /// Effective member cap for one SLO class.
    pub fn max_batch_for(&self, p: Priority) -> usize {
        self.per_priority[p.index()]
            .max_batch
            .unwrap_or(self.max_batch)
            .max(1)
    }

    /// Effective window cap for one SLO class.
    pub fn max_wait_for(&self, p: Priority) -> Duration {
        self.per_priority[p.index()].max_wait.unwrap_or(self.max_wait)
    }

    /// True when this class takes the single-request path (no batch is
    /// ever formed for it).
    pub fn bypasses(&self, p: Priority) -> bool {
        self.max_batch_for(p) <= 1
    }

    /// True when at least one SLO class bypasses batching — the
    /// condition under which a multi-worker stage reserves worker 0 as
    /// the bypass fast lane. When nothing bypasses, there is no lane to
    /// reserve and every worker batches.
    pub fn any_bypass(&self) -> bool {
        Priority::ALL.iter().any(|p| self.bypasses(*p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settings() -> BatchSettings {
        BatchSettings {
            max_batch: 8,
            max_wait_us: 2_000,
            adaptive: true,
            interactive_bypass: true,
            max_starvation_ms: 0,
        }
    }

    #[test]
    fn interactive_bypass_pins_class_to_single() {
        let p = BatchPolicy::from_settings(&settings());
        assert!(p.bypasses(Priority::Interactive));
        assert_eq!(p.max_batch_for(Priority::Interactive), 1);
        assert_eq!(p.max_wait_for(Priority::Interactive), Duration::ZERO);
        // The coalescing classes inherit the stage-wide knobs.
        for q in [Priority::Standard, Priority::Batch] {
            assert_eq!(p.max_batch_for(q), 8);
            assert_eq!(p.max_wait_for(q), Duration::from_micros(2_000));
            assert!(!p.bypasses(q));
        }
    }

    #[test]
    fn bypass_off_batches_every_class() {
        let mut s = settings();
        s.interactive_bypass = false;
        let p = BatchPolicy::from_settings(&s);
        assert!(!p.bypasses(Priority::Interactive));
        assert_eq!(p.max_batch_for(Priority::Interactive), 8);
        // No class bypasses → no fast lane is reserved.
        assert!(!p.any_bypass());
        assert!(BatchPolicy::from_settings(&settings()).any_bypass());
    }

    #[test]
    fn explicit_class_override_wins() {
        let mut p = BatchPolicy::from_settings(&settings());
        p.per_priority[Priority::Batch.index()] = ClassPolicy {
            max_batch: Some(32),
            max_wait: Some(Duration::from_millis(10)),
        };
        assert_eq!(p.max_batch_for(Priority::Batch), 32);
        assert_eq!(p.max_wait_for(Priority::Batch), Duration::from_millis(10));
        // Zero-sized overrides clamp to a real batch of one.
        p.per_priority[Priority::Standard.index()].max_batch = Some(0);
        assert_eq!(p.max_batch_for(Priority::Standard), 1);
        assert!(p.bypasses(Priority::Standard));
    }
}
