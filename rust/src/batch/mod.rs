//! Adaptive micro-batching for the stage data plane (§4.3–§4.5
//! extended).
//!
//! The paper's stage executors pay full per-invocation overhead for
//! every request: weight streaming, kernel launch, and the
//! `AppLogic::execute` dispatch all repeat per message, and the
//! [`crate::workflow::SchedQueue`] hands TaskWorkers exactly one
//! `WorkflowMessage` at a time. Diffusion-style stages amortize most of
//! that cost across a batch — micro-served diffusion serving gains the
//! bulk of its throughput from stage-local request batching — so this
//! module inserts an **adaptive micro-batching engine** between the
//! scheduler queue and the workers:
//!
//! - [`BatchPolicy`] — per-stage knobs from the config `batch` block:
//!   `max_batch`, the formation window `max_wait`, and per-priority
//!   overrides so Interactive traffic bypasses batching entirely while
//!   Batch-tier traffic coalesces aggressively.
//! - [`BatchAssembler`] — drains *compatible* messages (same app, same
//!   stage, same priority band) from the queue into a [`MicroBatch`],
//!   closing on size, on the **deadline of the oldest member** (never
//!   wait a request past its SLO to fatten a batch), or on window
//!   expiry.
//! - [`AdaptiveWindow`] — resizes the effective window from observed
//!   fill and backlog plus the §4.2 utilization reports: low utilization
//!   shrinks the window (latency mode), backlog grows it toward
//!   `max_batch` (throughput mode). The current window is exported to
//!   the NodeManager alongside the utilization heartbeat
//!   ([`crate::workflow::ControlPlane::report_batch_window`]) so §8.2
//!   elastic reallocation and batch sizing don't fight each other.
//!
//! Batching is **off by default**: without a config `batch` block the
//! worker loop takes the single-request path unchanged. Collaboration
//! Mode never batches (one broadcast request occupies all ranks), and
//! when a stage runs more than one worker, worker 0 becomes a
//! **reserved fast lane** serving only the bypass classes — without the
//! reservation, bypass would skip batch *formation* but still wait
//! behind a worker pool entirely mid-batch (head-of-line blocking),
//! costing bypassing traffic the very tail latency it was promised.
//! Mirrors the proxy's `interactive_reserve`: a slice of capacity is
//! the price of the latency guarantee. Single-worker stages have no
//! lane to spare; there, Interactive bypass skips formation only.

mod adaptive;
mod assembler;
mod policy;

pub use adaptive::AdaptiveWindow;
pub use assembler::{BatchAssembler, MicroBatch};
pub use policy::{BatchPolicy, ClassPolicy};
