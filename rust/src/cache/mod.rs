//! Content-addressed artifact cache across workflow stages (ROADMAP
//! item 1; DESIGN.md §Artifact cache).
//!
//! AIGC traffic is heavily repetitive — identical prompts, shared
//! text-encoder embeddings, re-runs of the same seed — so whole stages
//! can be skipped when the same `(app, stage, salt, input)` computation
//! has already run anywhere in the set. This module provides that skip:
//!
//! - [`key`]: 128-bit content-addressed keys over the canonicalized
//!   stage input ([`crate::transport::Payload::encode`]), salted by
//!   deployment config so a model bump invalidates everything.
//! - [`tier`]: the two-tier store. Hot = bounded in-process LRU of
//!   `Arc<[u8]>`. Warm = the same entries staged once into registered
//!   [`crate::rdma::PayloadStager`] slabs, readable by ONE one-sided
//!   READ from any instance — the PR 6 rendezvous plane reused as a
//!   storage tier.
//! - [`singleflight`]: concurrent identical misses compute once;
//!   followers wait on the leader's condvar instead of duplicating GPU
//!   work.
//!
//! [`ArtifactCache`] is the façade the proxy (full-workflow hits at
//! admission), the instance worker loop (per-stage hits before
//! `execute`/`execute_batch`), and [`crate::workflow::ResultDeliver`]
//! (workflow-tier fill on terminal store) share. Fills are idempotent
//! first-writer-wins, mirroring MemDb's result semantics: racing fills
//! never double-publish, the loser's bytes are simply dropped.
//!
//! Everything is off unless the cluster config carries a `cache` block;
//! with no block the request path is byte-identical to an uncached
//! build (no `ArtifactCache` is even constructed).

pub mod key;
pub mod singleflight;
pub mod tier;

pub use key::{derive_key, CacheKey, WORKFLOW_STAGE};
pub use singleflight::{Flight, FlightGuard, FlightWait, SingleFlight};
pub use tier::{InsertOutcome, Lookup, TierStore};

use crate::config::CacheSettings;
use crate::lint::runtime::{WitnessMutex, RANK_CACHE_STORE};
use crate::metrics::{Counter, Registry};
use crate::rdma::{Fabric, PayloadDescriptor};
use crate::transport::{AppId, Payload};
use crate::util::{frame_checksum, Clock, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Pending workflow-key notes are dropped past this age even if the
/// request never produced a terminal result (cancelled upstream of the
/// database, proxy rollback, ...). Keeps the map bounded.
const PENDING_TTL_NS: u64 = 600_000_000_000; // 10 min
/// Hard bound on in-flight workflow notes; beyond it new notes are
/// refused (the request simply won't seed the workflow tier).
const PENDING_MAX: usize = 65_536;

struct CacheMetrics {
    registry: Registry,
    /// `cache_hits.<stage>` / `cache_misses.<stage>`, created on first
    /// touch and memoized so the hot path skips the registry lock.
    per_stage: Mutex<HashMap<String, (Arc<Counter>, Arc<Counter>)>>, // lint: lock-rank(cache_per_stage, 49)
    evictions: Arc<Counter>,
    bytes_saved: Arc<Counter>,
    coalesced: Arc<Counter>,
    warm_reads: Arc<Counter>,
    fills: Arc<Counter>,
    /// The shared data-plane copy meter: a fill charges exactly ONE
    /// staging copy (the PR 6 accounting invariant the warm tier
    /// preserves — K later hits add zero).
    copied: Arc<Counter>,
}

impl CacheMetrics {
    fn new(registry: &Registry) -> Self {
        Self {
            registry: registry.clone(),
            per_stage: Mutex::new(HashMap::new()),
            evictions: registry.counter("cache_evictions_total"),
            bytes_saved: registry.counter("cache_bytes_saved_total"),
            coalesced: registry.counter("cache_coalesced_total"),
            warm_reads: registry.counter("cache_warm_reads_total"),
            fills: registry.counter("cache_fills_total"),
            copied: registry.counter("payload_bytes_copied_total"),
        }
    }

    fn stage_pair(&self, stage: &str) -> (Arc<Counter>, Arc<Counter>) {
        let mut m = self.per_stage.lock().unwrap();
        m.entry(stage.to_string())
            .or_insert_with(|| {
                (
                    self.registry.counter(&format!("cache_hits.{stage}")),
                    self.registry.counter(&format!("cache_misses.{stage}")),
                )
            })
            .clone()
    }
}

/// One set's artifact cache: content-addressed lookups, two-tier
/// storage, single-flight miss coalescing, first-writer-wins fills.
pub struct ArtifactCache {
    fabric: Fabric,
    clock: Arc<dyn Clock>,
    salt: String,
    /// Stage names the per-stage tier engages for; empty = every stage.
    stages: Vec<String>,
    workflow: bool,
    store: WitnessMutex<TierStore>, // lint: lock-rank(cache_store, 50)
    flights: SingleFlight,
    /// uid → (workflow key, noted_at): misses remembered at admission so
    /// the terminal store can seed the full-workflow tier.
    pending: Mutex<HashMap<u128, (CacheKey, u64)>>, // lint: lock-rank(cache_pending, 52)
    metrics: CacheMetrics,
}

impl ArtifactCache {
    pub fn new(
        fabric: Fabric,
        clock: Arc<dyn Clock>,
        settings: &CacheSettings,
        registry: &Registry,
    ) -> Self {
        let store = TierStore::new(
            fabric.clone(),
            settings.hot_capacity_bytes,
            settings.warm_capacity_bytes,
            settings.ttl_ms.saturating_mul(1_000_000),
        );
        Self {
            fabric,
            clock,
            salt: settings.salt.clone(),
            stages: settings.stages.clone(),
            workflow: settings.workflow,
            store: WitnessMutex::new("cache_store", RANK_CACHE_STORE, store),
            flights: SingleFlight::new(),
            pending: Mutex::new(HashMap::new()),
            metrics: CacheMetrics::new(registry),
        }
    }

    /// Is the per-stage tier on for this stage name?
    pub fn stage_enabled(&self, stage: &str) -> bool {
        self.stages.is_empty() || self.stages.iter().any(|s| s == stage)
    }

    /// Is the full-workflow admission tier on?
    pub fn workflow_enabled(&self) -> bool {
        self.workflow
    }

    /// Content-addressed key for one stage computation under this
    /// cache's salt. Use [`WORKFLOW_STAGE`] for the admission tier.
    pub fn key_for(&self, app: AppId, stage: &str, input: &Payload) -> CacheKey {
        derive_key(app, stage, &self.salt, input)
    }

    /// Look `key` up, counting a hit or miss under `stage`'s label. A
    /// hot hit is a pointer clone; a warm hit performs one one-sided
    /// READ against the staged slab (exactly the endpoint's rendezvous
    /// pull) and promotes the bytes back into the hot tier.
    pub fn lookup(&self, stage: &str, key: CacheKey) -> Option<Arc<[u8]>> {
        let (hits, misses) = self.metrics.stage_pair(stage);
        let now = self.clock.now_ns();
        let mut store = self.store.lock().unwrap();
        match store.get(key.0, now) {
            Lookup::Hot(v) => {
                hits.inc();
                self.metrics.bytes_saved.add(v.len() as u64);
                Some(v)
            }
            Lookup::Warm(desc, len) => match self.read_warm(&desc, len) {
                Some(v) => {
                    store.promote(key.0, v.clone());
                    hits.inc();
                    self.metrics.bytes_saved.add(v.len() as u64);
                    Some(v)
                }
                None => {
                    // Unvalidatable slab (should not happen for our own
                    // pinned slabs) — serve a miss rather than bad bytes.
                    misses.inc();
                    None
                }
            },
            Lookup::Miss => {
                misses.inc();
                None
            }
        }
    }

    /// One vectored one-sided READ covering slab header + payload, then
    /// generation + checksum validation — the same recipe as
    /// `RdmaEndpoint::pull_payload`, against a cache-owned slab. No
    /// release Fetch&Add: cache slabs are pinned and reclaimed only by
    /// eviction.
    fn read_warm(&self, desc: &PayloadDescriptor, len: usize) -> Option<Arc<[u8]>> {
        let off = desc.offset as usize;
        if off % 8 != 0 {
            return None;
        }
        let qp = self.fabric.connect(desc.region).ok()?;
        let hdr_words = off / 8;
        let mut words = vec![0u64; hdr_words + len.div_ceil(8)];
        qp.post_read_words(0, &mut words).ok()?;
        if words[0] != desc.generation {
            return None; // evicted and re-staged under us
        }
        let mut payload = vec![0u8; len];
        for (i, chunk) in payload.chunks_mut(8).enumerate() {
            let b = words[hdr_words + i].to_le_bytes();
            chunk.copy_from_slice(&b[..chunk.len()]);
        }
        if frame_checksum(&payload) as u64 != desc.checksum {
            return None;
        }
        // Verb accounting (lint L4): the validated READ is counted where
        // it is issued, so the e16 warm-read numbers can't drift from
        // the verb budget.
        self.metrics.warm_reads.inc();
        Some(payload.into())
    }

    /// First-writer-wins fill. Returns whether this call published the
    /// value. The single staging copy of the entry's life is charged to
    /// `payload_bytes_copied_total` here; hits never add to it.
    pub fn fill(&self, key: CacheKey, value: &Arc<[u8]>) -> bool {
        let now = self.clock.now_ns();
        let mut store = self.store.lock().unwrap();
        match store.insert(key.0, value, now) {
            InsertOutcome::Inserted { evicted } => {
                self.metrics.fills.inc();
                self.metrics.copied.add(value.len() as u64);
                self.metrics.evictions.add(evicted as u64);
                true
            }
            InsertOutcome::Duplicate | InsertOutcome::TooLarge => false,
        }
    }

    /// Join or open the single-flight for `key`. Followers are counted
    /// as coalesced work.
    pub fn begin_flight(&self, key: CacheKey) -> Flight {
        let f = self.flights.begin(key);
        if matches!(f, Flight::Follower(_)) {
            self.metrics.coalesced.inc();
        }
        f
    }

    /// Remember that `uid` was admitted as a miss under workflow `key`,
    /// so the terminal store can seed the admission tier.
    pub fn note_workflow_key(&self, uid: Uid, key: CacheKey) {
        if !self.workflow {
            return;
        }
        let now = self.clock.now_ns();
        let mut p = self.pending.lock().unwrap();
        if p.len() >= PENDING_MAX {
            p.retain(|_, (_, at)| now.saturating_sub(*at) < PENDING_TTL_NS);
            if p.len() >= PENDING_MAX {
                return;
            }
        }
        p.insert(uid.0, (key, now));
    }

    /// Called by the delivery plane when `uid`'s terminal result is
    /// stored: fill the full-workflow tier with the encoded terminal
    /// message. Returns whether a fill was published.
    pub fn complete_workflow(&self, uid: Uid, value: &Arc<[u8]>) -> bool {
        let key = {
            let mut p = self.pending.lock().unwrap();
            match p.remove(&uid.0) {
                Some((k, _)) => k,
                None => return false,
            }
        };
        self.fill(key, value)
    }

    /// Housekeeper hook: evict TTL-expired entries and forget stale
    /// pending workflow notes. Returns evicted entry count.
    pub fn purge_expired(&self) -> usize {
        let now = self.clock.now_ns();
        let evicted = self.store.lock().unwrap().purge_expired(now);
        self.metrics.evictions.add(evicted as u64);
        self.pending
            .lock()
            .unwrap()
            .retain(|_, (_, at)| now.saturating_sub(*at) < PENDING_TTL_NS);
        evicted
    }

    /// Cached entries (tests / introspection).
    pub fn len(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes held by each tier: `(hot, warm)`.
    pub fn tier_bytes(&self) -> (usize, usize) {
        self.store.lock().unwrap().bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SystemClock;

    fn settings() -> CacheSettings {
        CacheSettings::default()
    }

    fn cache_with(settings: CacheSettings) -> (Arc<ArtifactCache>, Registry) {
        let reg = Registry::new();
        let c = ArtifactCache::new(
            Fabric::ideal(),
            Arc::new(SystemClock),
            &settings,
            &reg,
        );
        (Arc::new(c), reg)
    }

    fn arc(bytes: &[u8]) -> Arc<[u8]> {
        Arc::from(bytes.to_vec())
    }

    #[test]
    fn fill_then_lookup_counts_hits_and_misses() {
        let (c, reg) = cache_with(settings());
        let k = c.key_for(AppId(1), "vae", &Payload::Bytes(b"in".to_vec()));
        assert!(c.lookup("vae", k).is_none());
        assert!(c.fill(k, &arc(b"out")));
        assert_eq!(&c.lookup("vae", k).unwrap()[..], b"out");
        assert_eq!(reg.counter("cache_hits.vae").get(), 1);
        assert_eq!(reg.counter("cache_misses.vae").get(), 1);
        assert_eq!(reg.counter("cache_bytes_saved_total").get(), 3);
    }

    #[test]
    fn fill_is_first_writer_wins() {
        let (c, _) = cache_with(settings());
        let k = CacheKey(42);
        assert!(c.fill(k, &arc(b"first")));
        assert!(!c.fill(k, &arc(b"second")));
        assert_eq!(&c.lookup("s", k).unwrap()[..], b"first");
    }

    #[test]
    fn hits_never_charge_the_copy_meter() {
        // The PR 6 follow-on invariant: one staging copy at fill, zero
        // per hit — K hits on a cached artifact cost 1×len total.
        let (c, reg) = cache_with(settings());
        let copied = reg.counter("payload_bytes_copied_total");
        let k = CacheKey(7);
        c.fill(k, &arc(&[9u8; 100]));
        assert_eq!(copied.get(), 100);
        for _ in 0..10 {
            assert!(c.lookup("s", k).is_some());
        }
        assert_eq!(copied.get(), 100, "hits add no copies");
    }

    #[test]
    fn warm_hit_reads_via_one_sided_read_and_promotes() {
        // Hot tier fits one value: filling a second demotes the first,
        // whose next lookup must come back via the slab READ path.
        let mut s = settings();
        s.hot_capacity_bytes = 64;
        let (c, reg) = cache_with(s);
        c.fill(CacheKey(1), &arc(&[1u8; 64]));
        c.fill(CacheKey(2), &arc(&[2u8; 64]));
        let v = c.lookup("s", CacheKey(1)).expect("warm hit");
        assert_eq!(&v[..], &[1u8; 64][..]);
        assert_eq!(reg.counter("cache_warm_reads_total").get(), 1);
        // Promoted: the next hit is hot again (no second warm read).
        assert!(c.lookup("s", CacheKey(1)).is_some());
        assert_eq!(reg.counter("cache_warm_reads_total").get(), 1);
        assert_eq!(reg.counter("cache_hits.s").get(), 2);
    }

    #[test]
    fn eviction_under_pressure_is_counted() {
        let mut s = settings();
        s.warm_capacity_bytes = 128;
        let (c, reg) = cache_with(s);
        c.fill(CacheKey(1), &arc(&[1u8; 64]));
        c.fill(CacheKey(2), &arc(&[2u8; 64]));
        c.fill(CacheKey(3), &arc(&[3u8; 64]));
        assert_eq!(reg.counter("cache_evictions_total").get(), 1);
        assert!(c.lookup("s", CacheKey(1)).is_none(), "LRU evicted");
        assert!(c.lookup("s", CacheKey(3)).is_some());
    }

    #[test]
    fn stage_enable_list_gates() {
        let mut s = settings();
        s.stages = vec!["vae_decode".into()];
        let (c, _) = cache_with(s);
        assert!(c.stage_enabled("vae_decode"));
        assert!(!c.stage_enabled("diffusion"));
        let (all, _) = cache_with(settings());
        assert!(all.stage_enabled("anything"), "empty list = all stages");
    }

    #[test]
    fn salt_selects_distinct_keys() {
        let mut a = settings();
        a.salt = "model-v1".into();
        let mut b = settings();
        b.salt = "model-v2".into();
        let (ca, _) = cache_with(a);
        let (cb, _) = cache_with(b);
        let p = Payload::Bytes(b"same input".to_vec());
        assert_ne!(ca.key_for(AppId(1), "s", &p), cb.key_for(AppId(1), "s", &p));
    }

    #[test]
    fn workflow_note_then_complete_seeds_admission_tier() {
        let (c, reg) = cache_with(settings());
        let p = Payload::Bytes(b"prompt".to_vec());
        let k = c.key_for(AppId(1), WORKFLOW_STAGE, &p);
        assert!(c.lookup("workflow", k).is_none());
        c.note_workflow_key(Uid(77), k);
        let terminal = arc(b"terminal message bytes");
        assert!(c.complete_workflow(Uid(77), &terminal));
        assert!(!c.complete_workflow(Uid(77), &terminal), "note consumed");
        assert_eq!(&c.lookup("workflow", k).unwrap()[..], &terminal[..]);
        assert_eq!(reg.counter("cache_hits.workflow").get(), 1);
    }

    #[test]
    fn follower_flights_count_as_coalesced() {
        let (c, reg) = cache_with(settings());
        let Flight::Leader(lead) = c.begin_flight(CacheKey(5)) else {
            panic!()
        };
        let Flight::Follower(w) = c.begin_flight(CacheKey(5)) else {
            panic!()
        };
        assert_eq!(reg.counter("cache_coalesced_total").get(), 1);
        lead.complete(arc(b"v"));
        assert_eq!(&w.wait(std::time::Duration::from_secs(1)).unwrap()[..], b"v");
    }
}
