//! The two-tier store behind [`crate::cache::ArtifactCache`].
//!
//! Every cached artifact is staged **once** into a registered
//! [`PayloadStager`] slab at fill time — that staging write is the only
//! host copy the cache ever performs for an entry, and it makes the
//! bytes readable by ONE one-sided READ from any instance on the fabric
//! (the PR 6 rendezvous plane reused as a storage tier). On top of the
//! slabs sits a bounded in-process **hot** tier of `Arc<[u8]>` handles:
//! a hot hit is a pointer clone, zero copies, zero verbs.
//!
//! Capacity pressure demotes, then evicts, in LRU order:
//! - hot over `hot_capacity_bytes` → drop the LRU `Arc` (the slab
//!   stays; the entry is still served via the warm READ path),
//! - warm over `warm_capacity_bytes` → unstage the LRU slab entirely
//!   (generation bump — a descriptor that leaked to a remote reader can
//!   never validate again) and forget the entry.
//!
//! TTL expiry runs on the set housekeeper's sweep and evicts whole
//! entries the same way. Slabs are staged with `readers = u64::MAX` so
//! the stager's own release-count reclaim never fires underneath us;
//! eviction is the only reclaim path.

use crate::rdma::{Fabric, PayloadDescriptor, PayloadStager};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

struct Entry {
    /// In-process fast path; `None` once demoted by hot-tier pressure.
    hot: Option<Arc<[u8]>>,
    hot_tick: u64,
    desc: PayloadDescriptor,
    len: usize,
    filled_at_ns: u64,
    warm_tick: u64,
}

/// Outcome of [`TierStore::insert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// First writer: the value is now cached. Carries the number of
    /// older entries fully evicted to make room.
    Inserted { evicted: usize },
    /// The key was already filled — first-writer-wins kept the old value.
    Duplicate,
    /// Larger than the warm tier itself; not cached.
    TooLarge,
}

/// Outcome of [`TierStore::get`].
pub enum Lookup {
    /// Served from the in-process tier (pointer clone).
    Hot(Arc<[u8]>),
    /// Present in a staged slab only: pull with one one-sided READ
    /// against the descriptor, then [`TierStore::promote`] the bytes.
    Warm(PayloadDescriptor, usize),
    Miss,
}

pub struct TierStore {
    stager: PayloadStager,
    hot_capacity: usize,
    warm_capacity: usize,
    /// 0 = entries never expire.
    ttl_ns: u64,
    tick: u64,
    hot_bytes: usize,
    warm_bytes: usize,
    entries: HashMap<u128, Entry>,
    /// Recency indexes: tick → key. Ticks are unique (monotone counter),
    /// so the BTreeMap head is always the LRU entry.
    hot_lru: BTreeMap<u64, u128>,
    warm_lru: BTreeMap<u64, u128>,
}

impl TierStore {
    pub fn new(fabric: Fabric, hot_capacity: usize, warm_capacity: usize, ttl_ns: u64) -> Self {
        Self {
            stager: PayloadStager::new(fabric),
            hot_capacity,
            warm_capacity,
            ttl_ns,
            tick: 0,
            hot_bytes: 0,
            warm_bytes: 0,
            entries: HashMap::new(),
            hot_lru: BTreeMap::new(),
            warm_lru: BTreeMap::new(),
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// First-writer-wins fill. The one staging copy of the entry's life
    /// happens here.
    pub fn insert(&mut self, key: u128, value: &Arc<[u8]>, now_ns: u64) -> InsertOutcome {
        if self.entries.contains_key(&key) {
            return InsertOutcome::Duplicate;
        }
        if value.len() > self.warm_capacity {
            return InsertOutcome::TooLarge;
        }
        // Pinned staging: u64::MAX expected releases means the stager's
        // lazy sweep can never reclaim the slab; `unstage` on eviction is
        // the only way back.
        let desc = self.stager.stage(value, u64::MAX);
        let hot_tick = self.next_tick();
        let warm_tick = self.tick;
        self.entries.insert(
            key,
            Entry {
                hot: Some(value.clone()),
                hot_tick,
                desc,
                len: value.len(),
                filled_at_ns: now_ns,
                warm_tick,
            },
        );
        self.hot_lru.insert(hot_tick, key);
        self.warm_lru.insert(warm_tick, key);
        self.hot_bytes += value.len();
        self.warm_bytes += value.len();
        self.demote_over_hot_capacity();
        let evicted = self.evict_over_warm_capacity(key);
        InsertOutcome::Inserted { evicted }
    }

    /// Look `key` up, expiring it first if its TTL passed.
    pub fn get(&mut self, key: u128, now_ns: u64) -> Lookup {
        let expired = match self.entries.get(&key) {
            None => return Lookup::Miss,
            Some(e) => {
                self.ttl_ns > 0 && now_ns.saturating_sub(e.filled_at_ns) >= self.ttl_ns
            }
        };
        if expired {
            self.evict(key);
            return Lookup::Miss;
        }
        let tick = self.next_tick();
        // Present: looked up above and not evicted since. A miss is the
        // safe answer if that invariant ever breaks.
        let Some(e) = self.entries.get_mut(&key) else {
            return Lookup::Miss;
        };
        self.warm_lru.remove(&e.warm_tick);
        e.warm_tick = tick;
        self.warm_lru.insert(tick, key);
        match &e.hot {
            Some(v) => {
                let v = v.clone();
                self.hot_lru.remove(&e.hot_tick);
                e.hot_tick = tick;
                self.hot_lru.insert(tick, key);
                Lookup::Hot(v)
            }
            None => Lookup::Warm(e.desc, e.len),
        }
    }

    /// Re-populate the hot tier after a warm READ (the pulled bytes are
    /// in hand anyway — keep them for the next local hit).
    pub fn promote(&mut self, key: u128, value: Arc<[u8]>) {
        let tick = self.next_tick();
        let Some(e) = self.entries.get_mut(&key) else { return };
        if e.hot.is_some() {
            return;
        }
        e.hot = Some(value);
        e.hot_tick = tick;
        self.hot_lru.insert(tick, key);
        self.hot_bytes += e.len;
        self.demote_over_hot_capacity();
    }

    /// Evict every entry whose TTL passed; returns how many.
    pub fn purge_expired(&mut self, now_ns: u64) -> usize {
        if self.ttl_ns == 0 {
            return 0;
        }
        let dead: Vec<u128> = self
            .entries
            .iter()
            .filter(|(_, e)| now_ns.saturating_sub(e.filled_at_ns) >= self.ttl_ns)
            .map(|(k, _)| *k)
            .collect();
        for k in &dead {
            self.evict(*k);
        }
        dead.len()
    }

    /// Drop LRU `Arc`s until the hot tier fits. Demotion keeps the slab:
    /// the entry stays servable through the warm READ path.
    fn demote_over_hot_capacity(&mut self) {
        while self.hot_bytes > self.hot_capacity {
            let Some((&tick, &key)) = self.hot_lru.iter().next() else { break };
            self.hot_lru.remove(&tick);
            if let Some(e) = self.entries.get_mut(&key) {
                if e.hot.take().is_some() {
                    self.hot_bytes -= e.len;
                }
            }
        }
    }

    /// Unstage LRU entries until the warm tier fits, never evicting the
    /// entry just inserted (`keep`). Returns how many were evicted.
    fn evict_over_warm_capacity(&mut self, keep: u128) -> usize {
        let mut evicted = 0;
        while self.warm_bytes > self.warm_capacity {
            let victim = self.warm_lru.iter().map(|(_, k)| *k).find(|k| *k != keep);
            match victim {
                Some(k) => {
                    self.evict(k);
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Remove `key` entirely: drop the hot handle and unstage the slab
    /// (generation bump — leaked descriptors strand, never corrupt).
    fn evict(&mut self, key: u128) {
        let Some(e) = self.entries.remove(&key) else { return };
        if e.hot.is_some() {
            self.hot_lru.remove(&e.hot_tick);
            self.hot_bytes -= e.len;
        }
        self.warm_lru.remove(&e.warm_tick);
        self.warm_bytes -= e.len;
        self.stager.unstage(&e.desc);
    }

    /// Cached entries (hot + warm-only).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries currently holding a hot `Arc`.
    pub fn hot_len(&self) -> usize {
        self.hot_lru.len()
    }

    /// Bytes held by each tier: `(hot, warm)`.
    pub fn bytes(&self) -> (usize, usize) {
        (self.hot_bytes, self.warm_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rdma::{PAYLOAD_GEN_OFF, PAYLOAD_HDR_BYTES};

    fn val(n: usize, b: u8) -> Arc<[u8]> {
        Arc::from(vec![b; n])
    }

    fn store(hot: usize, warm: usize, ttl: u64) -> (TierStore, Fabric) {
        let fabric = Fabric::ideal();
        (TierStore::new(fabric.clone(), hot, warm, ttl), fabric)
    }

    #[test]
    fn first_writer_wins() {
        let (mut s, _f) = store(1 << 20, 1 << 20, 0);
        assert_eq!(s.insert(1, &val(8, 0xAA), 0), InsertOutcome::Inserted { evicted: 0 });
        assert_eq!(s.insert(1, &val(8, 0xBB), 0), InsertOutcome::Duplicate);
        match s.get(1, 0) {
            Lookup::Hot(v) => assert_eq!(&v[..], &[0xAA; 8][..]),
            _ => panic!("hot hit expected"),
        }
    }

    #[test]
    fn hot_pressure_demotes_to_warm_and_read_back_via_slab() {
        // Hot fits one 64-byte value; warm fits plenty.
        let (mut s, fabric) = store(64, 1 << 20, 0);
        s.insert(1, &val(64, 1), 0);
        s.insert(2, &val(64, 2), 0);
        assert_eq!(s.hot_len(), 1, "LRU hot entry demoted");
        assert_eq!(s.len(), 2, "demotion keeps the entry");
        let Lookup::Warm(desc, len) = s.get(1, 0) else {
            panic!("demoted entry is warm")
        };
        assert_eq!(len, 64);
        // The slab is readable through the fabric (the warm READ path).
        let slab = fabric.local(desc.region).unwrap();
        assert_eq!(slab.load_u64(PAYLOAD_GEN_OFF), desc.generation);
        let mut out = vec![0u8; len];
        slab.read_bytes(PAYLOAD_HDR_BYTES, &mut out);
        assert_eq!(out, vec![1u8; 64]);
        // Promote restores the hot fast path (and demotes key 2 in turn).
        s.promote(1, out.into());
        assert!(matches!(s.get(1, 0), Lookup::Hot(_)));
    }

    #[test]
    fn warm_pressure_evicts_lru_entirely() {
        let (mut s, fabric) = store(1 << 20, 128, 0);
        s.insert(1, &val(64, 1), 0);
        s.insert(2, &val(64, 2), 0);
        let Lookup::Hot(_) = s.get(1, 0) else { panic!() }; // touch: 2 is now LRU
        let InsertOutcome::Inserted { evicted } = s.insert(3, &val(64, 3), 0) else {
            panic!()
        };
        assert_eq!(evicted, 1);
        assert!(matches!(s.get(2, 0), Lookup::Miss), "LRU entry fully evicted");
        assert!(matches!(s.get(1, 0), Lookup::Hot(_)));
        assert!(matches!(s.get(3, 0), Lookup::Hot(_)));
        // The evicted slab's generation moved: a leaked descriptor can
        // never validate (strand-not-corrupt, as in the delivery plane).
        let (_, warm_bytes) = s.bytes();
        assert!(warm_bytes <= 128);
        drop(s);
        drop(fabric);
    }

    #[test]
    fn value_bigger_than_warm_tier_is_not_cached() {
        let (mut s, _f) = store(1 << 20, 64, 0);
        assert_eq!(s.insert(1, &val(65, 1), 0), InsertOutcome::TooLarge);
        assert!(s.is_empty());
    }

    #[test]
    fn ttl_expires_on_get_and_purge() {
        let (mut s, _f) = store(1 << 20, 1 << 20, 100);
        s.insert(1, &val(8, 1), 0);
        s.insert(2, &val(8, 2), 50);
        assert!(matches!(s.get(1, 99), Lookup::Hot(_)), "not yet expired");
        assert!(matches!(s.get(1, 100), Lookup::Miss), "expired on access");
        assert_eq!(s.purge_expired(149), 0, "key 2 still fresh");
        assert_eq!(s.purge_expired(150), 1, "key 2 swept");
        assert!(s.is_empty());
    }

    #[test]
    fn ttl_zero_never_expires() {
        let (mut s, _f) = store(1 << 20, 1 << 20, 0);
        s.insert(1, &val(8, 1), 0);
        assert!(matches!(s.get(1, u64::MAX), Lookup::Hot(_)));
        assert_eq!(s.purge_expired(u64::MAX), 0);
    }
}
