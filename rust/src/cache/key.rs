//! Content-addressed cache-key derivation (DESIGN.md §Artifact cache).
//!
//! A key names one stage computation: `hash(app, stage, salt,
//! canonicalized stage input)`. The salt folds in everything about the
//! deployment that changes outputs without changing inputs (model
//! revision, sampler config, artifact build) — bumping it invalidates
//! the whole cache without a flush protocol. The canonicalized input is
//! [`Payload::encode`], the deterministic message wire format minus the
//! header, so per-request fields (`uid`, `ts_ns`, origin) can never
//! reach the hash.
//!
//! The hash is two independent 64-bit FNV-1a lanes (different offset
//! bases) concatenated into 128 bits. FNV is not collision-resistant
//! against adversaries, but cache keys here are derived from trusted
//! in-cluster inputs; 128 bits makes accidental collisions negligible
//! at any realistic cache population.

use crate::transport::{AppId, Payload};

/// A 128-bit content-addressed cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey(pub u128);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Second-lane offset basis: the standard basis perturbed by the
/// golden-ratio constant so the two lanes never agree.
const FNV_OFFSET_HI: u64 = FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15;

/// Two-lane FNV-1a streaming hasher.
struct Fnv2 {
    lo: u64,
    hi: u64,
}

impl Fnv2 {
    fn new() -> Self {
        Self { lo: FNV_OFFSET, hi: FNV_OFFSET_HI }
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.lo = (self.lo ^ b as u64).wrapping_mul(FNV_PRIME);
            self.hi = (self.hi ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(self) -> u128 {
        ((self.hi as u128) << 64) | self.lo as u128
    }
}

/// The pseudo-stage name keying full-workflow results (proxy admission
/// tier): the terminal output of the whole chain for one entrance input.
pub const WORKFLOW_STAGE: &str = "__workflow__";

/// Derive the content-addressed key for one stage computation. Every
/// component is length-prefixed before hashing so field boundaries
/// cannot alias (`("ab","c")` vs `("a","bc")`).
pub fn derive_key(app: AppId, stage: &str, salt: &str, input: &Payload) -> CacheKey {
    let mut h = Fnv2::new();
    h.update(&app.0.to_le_bytes());
    h.update(&(stage.len() as u32).to_le_bytes());
    h.update(stage.as_bytes());
    h.update(&(salt.len() as u32).to_le_bytes());
    h.update(salt.as_bytes());
    h.update(&input.encode());
    CacheKey(h.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(b: &[u8]) -> Payload {
        Payload::Bytes(b.to_vec())
    }

    #[test]
    fn key_is_deterministic() {
        let a = derive_key(AppId(1), "diffusion", "v1", &payload(b"x"));
        let b = derive_key(AppId(1), "diffusion", "v1", &payload(b"x"));
        assert_eq!(a, b);
    }

    #[test]
    fn every_component_keys() {
        let base = derive_key(AppId(1), "s", "v1", &payload(b"x"));
        assert_ne!(base, derive_key(AppId(2), "s", "v1", &payload(b"x")), "app");
        assert_ne!(base, derive_key(AppId(1), "t", "v1", &payload(b"x")), "stage");
        assert_ne!(base, derive_key(AppId(1), "s", "v2", &payload(b"x")), "salt");
        assert_ne!(base, derive_key(AppId(1), "s", "v1", &payload(b"y")), "input");
    }

    #[test]
    fn field_boundaries_do_not_alias() {
        let a = derive_key(AppId(1), "ab", "c", &payload(b""));
        let b = derive_key(AppId(1), "a", "bc", &payload(b""));
        assert_ne!(a, b);
    }

    #[test]
    fn tensor_payloads_key_on_content() {
        let a = Payload::Tensor { shape: vec![2], data: vec![1.0, 2.0] };
        let b = Payload::Tensor { shape: vec![2], data: vec![1.0, 3.0] };
        assert_ne!(
            derive_key(AppId(1), "s", "", &a),
            derive_key(AppId(1), "s", "", &b)
        );
        // Shape participates too: same data, different view.
        let c = Payload::Tensor { shape: vec![1, 2], data: vec![1.0, 2.0] };
        assert_ne!(
            derive_key(AppId(1), "s", "", &a),
            derive_key(AppId(1), "s", "", &c)
        );
    }
}
