//! Single-flight coalescing: concurrent identical cache misses compute
//! once. The first worker to miss a key becomes the flight's *leader*
//! and executes the stage; every later worker that misses the same key
//! while the flight is open becomes a *follower* and blocks on the
//! flight's condvar instead of duplicating GPU work.
//!
//! The leader's handle is RAII: completing it publishes the value and
//! wakes every follower; dropping it without completing (stage error,
//! crash injection, shutdown mid-iteration) marks the flight abandoned
//! and wakes them too, so a follower can never outlive its leader in a
//! wait. Followers that time out or observe an abandon fall back to
//! executing themselves — coalescing is an optimization, never a
//! correctness dependency.

use super::key::CacheKey;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

enum FlightState {
    InFlight,
    Done(Arc<[u8]>),
    Abandoned,
}

struct FlightInner {
    state: Mutex<FlightState>, // lint: lock-rank(singleflight_state, 56)
    cv: Condvar,
}

type FlightMap = Arc<Mutex<HashMap<u128, Arc<FlightInner>>>>;

/// Registry of open flights, one per cache key.
pub struct SingleFlight {
    flights: FlightMap, // lint: lock-rank(singleflight, 55)
}

/// What [`SingleFlight::begin`] hands a worker.
pub enum Flight {
    /// First to miss: execute the stage, then [`FlightGuard::complete`].
    Leader(FlightGuard),
    /// A flight for this key is already open: wait on it.
    Follower(FlightWait),
}

impl SingleFlight {
    pub fn new() -> Self {
        Self { flights: Arc::new(Mutex::new(HashMap::new())) }
    }

    /// Join or open the flight for `key`.
    pub fn begin(&self, key: CacheKey) -> Flight {
        let mut map = self.flights.lock().unwrap();
        if let Some(inner) = map.get(&key.0) {
            return Flight::Follower(FlightWait { inner: inner.clone() });
        }
        let inner = Arc::new(FlightInner {
            state: Mutex::new(FlightState::InFlight),
            cv: Condvar::new(),
        });
        map.insert(key.0, inner.clone());
        Flight::Leader(FlightGuard {
            flights: self.flights.clone(),
            key,
            inner,
            finished: false,
        })
    }

    /// Open flights (tests / introspection).
    pub fn open(&self) -> usize {
        self.flights.lock().unwrap().len()
    }
}

impl Default for SingleFlight {
    fn default() -> Self {
        Self::new()
    }
}

/// Leader handle for one open flight.
pub struct FlightGuard {
    flights: FlightMap,
    key: CacheKey,
    inner: Arc<FlightInner>,
    finished: bool,
}

impl FlightGuard {
    /// Publish the computed value and wake all followers.
    pub fn complete(mut self, value: Arc<[u8]>) {
        self.finish(FlightState::Done(value));
    }

    fn finish(&mut self, state: FlightState) {
        self.finished = true;
        {
            // Remove first (under the map lock) so a racing `begin` after
            // the wake starts a fresh flight instead of joining a closed
            // one; the removal only drops *this* flight (a replacement
            // under the same key stays).
            let mut map = self.flights.lock().unwrap();
            if map.get(&self.key.0).is_some_and(|e| Arc::ptr_eq(e, &self.inner)) {
                map.remove(&self.key.0);
            }
        }
        *self.inner.state.lock().unwrap() = state;
        self.inner.cv.notify_all();
    }
}

impl Drop for FlightGuard {
    fn drop(&mut self) {
        if !self.finished {
            // Leader died without a value (stage error, crash, shutdown):
            // followers must not wait out their full timeout.
            self.finish(FlightState::Abandoned);
        }
    }
}

/// Follower handle: wait for the leader's value.
pub struct FlightWait {
    inner: Arc<FlightInner>,
}

impl FlightWait {
    /// Block until the leader completes, abandons, or `timeout` passes.
    /// `None` means "compute it yourself".
    pub fn wait(self, timeout: Duration) -> Option<Arc<[u8]>> {
        let deadline = Instant::now() + timeout;
        let mut state = self.inner.state.lock().unwrap();
        loop {
            match &*state {
                FlightState::Done(v) => return Some(v.clone()),
                FlightState::Abandoned => return None,
                FlightState::InFlight => {}
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            let (s, timed_out) = self.inner.cv.wait_timeout(state, left).unwrap();
            state = s;
            if timed_out.timed_out() {
                return match &*state {
                    FlightState::Done(v) => Some(v.clone()),
                    _ => None,
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn key(n: u128) -> CacheKey {
        CacheKey(n)
    }

    #[test]
    fn second_begin_is_a_follower() {
        let sf = SingleFlight::new();
        let Flight::Leader(lead) = sf.begin(key(1)) else {
            panic!("first begin must lead")
        };
        assert!(matches!(sf.begin(key(1)), Flight::Follower(_)));
        assert!(matches!(sf.begin(key(2)), Flight::Leader(_)), "keys are independent");
        lead.complete(Arc::from(&b"v"[..]));
        assert!(matches!(sf.begin(key(1)), Flight::Leader(_)), "completed flight closes");
    }

    #[test]
    fn followers_get_the_leaders_value() {
        let sf = Arc::new(SingleFlight::new());
        let Flight::Leader(lead) = sf.begin(key(7)) else { panic!() };
        let got = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::new();
        for _ in 0..4 {
            let Flight::Follower(w) = sf.begin(key(7)) else { panic!() };
            let got = got.clone();
            threads.push(std::thread::spawn(move || {
                let v = w.wait(Duration::from_secs(5)).expect("leader completes");
                assert_eq!(&v[..], b"out");
                got.fetch_add(1, Ordering::SeqCst);
            }));
        }
        std::thread::sleep(Duration::from_millis(20));
        lead.complete(Arc::from(&b"out"[..]));
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(got.load(Ordering::SeqCst), 4);
        assert_eq!(sf.open(), 0);
    }

    #[test]
    fn abandoned_leader_wakes_followers_empty_handed() {
        let sf = SingleFlight::new();
        let Flight::Leader(lead) = sf.begin(key(3)) else { panic!() };
        let Flight::Follower(w) = sf.begin(key(3)) else { panic!() };
        let t = std::thread::spawn(move || w.wait(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        drop(lead); // no complete(): stage errored
        assert_eq!(t.join().unwrap(), None, "follower computes itself");
        assert_eq!(sf.open(), 0, "abandoned flight closes");
    }

    #[test]
    fn wait_times_out() {
        let sf = SingleFlight::new();
        let Flight::Leader(_lead) = sf.begin(key(9)) else { panic!() };
        let Flight::Follower(w) = sf.begin(key(9)) else { panic!() };
        assert_eq!(w.wait(Duration::from_millis(30)), None);
    }
}
