//! OnePiece leader binary: CLI for running a Workflow Set, federating
//! several sets behind the global load-aware router, printing pipeline
//! plans / schedule traces, and driving the resource simulator.
//!
//! Argument parsing is hand-rolled (the offline build has no clap); see
//! `onepiece help` for usage.

use anyhow::{bail, Context, Result};
use onepiece::client::{
    Gateway, Priority, RequestHandle, RequestStatus, RetryPolicy, SubmitOptions,
    WaitOutcome,
};
use onepiece::config::{ClusterConfig, ExecModel};
use onepiece::federation::{FederationConfig, FederationRouter};
use onepiece::pipeline::{trace_schedule, TraceStage};
use onepiece::sim::{
    simulate_disaggregated, simulate_monolithic, wan_stages, ArrivalProcess,
    ResourceSimConfig,
};
use onepiece::transport::{AppId, Payload};
use onepiece::util::now_ns;
use onepiece::wset::{build_pool, WorkflowSet};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const HELP: &str = "\
onepiece — distributed AIGC inference (paper reproduction)

USAGE:
  onepiece serve [--requests N] [--steps S] [--artifacts DIR] [--sim]
      Run one Workflow Set end-to-end (PJRT stage executables unless
      --sim) and report latency/throughput.
  onepiece federate [--sets N] [--rate R] [--duration S] [--kill-every S]
                    [--fault-rate P] [--partition S] [--config PATH]
                    [--cache] --sim
      Run N Workflow Sets behind the global load-aware FederationRouter
      under bursty (MMPP) load with an Interactive/Standard/Batch SLO
      mix; report per-set throughput, spill count, reject rate,
      cross-set donations, per-priority admission, and
      cancelled/deadline-missed lifecycle counts. --kill-every S turns
      on chaos mode: each set's housekeeper kills one assigned instance
      every S seconds; the failure detector evicts it, promotes a
      replacement, and replays stranded requests from checkpoints
      (instances_failed / requests_recovered / requests_failed are
      reported). --fault-rate P injects seeded verb loss with
      probability P on every set's fabric (the `faults` config block);
      --partition S cuts a directed node-pair partition a third of the
      way in and heals it after S seconds. Either flag adds a breaker /
      brownout / fault-counter summary. --config PATH loads a cluster
      config JSON as the base (e.g. examples/configs/cached_i2v.json);
      --cache enables the artifact cache with defaults. With the cache
      on, prompts are drawn Zipf-distributed so repeats exist, and
      cache hit/miss/coalesce counters are reported.
  onepiece plan [--entrance N]
      Print the Theorem-1 instance plan for the i2v pipeline.
  onepiece trace (--fig5 | --fig6)
      Print the paper's Figure 5/6 pipelining schedule.
  onepiece trace --config PATH [--requests N] [--json]
      Run a traced Workflow Set (the config's `trace` block; defaults
      to sample_rate 1.0 if absent) against N simulated requests and
      print the per-stage queue/exec/transit p50/p95/p99 breakdown plus
      exemplar slow traces with their critical paths. --json appends a
      machine-readable report (e.g. examples/configs/traced_i2v.json).
  onepiece sim-resources [--pattern poisson|mmpp|diurnal] [--peak R]
      Run the E1 monolithic-vs-disaggregated GPU-resource comparison.
  onepiece info [--artifacts DIR]
      Show artifact manifest and PJRT platform.
  onepiece lint [--src DIR] [--json PATH] [--baseline PATH]
                [--write-baseline]
      Run the in-crate static-analysis pass (rules L1-L5: data-plane
      panic paths, unbounded Condvar waits, lock-rank order, RDMA verb
      accounting, cache-key determinism) over the crate's own source
      tree (default rust/src). Writes a machine-readable report
      (default LINT_REPORT.json) and exits non-zero on violations.
      --baseline filters acknowledged fingerprints (default
      LINT_BASELINE.json when present); --write-baseline accepts the
      current violations wholesale into the baseline file.
  onepiece help
      This text.
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(key.to_string(), val);
        }
        i += 1;
    }
    m
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "serve" => serve(&flags),
        "federate" => federate(&flags),
        "plan" => plan(&flags),
        "trace" => trace(&flags),
        "sim-resources" => sim_resources(&flags),
        "info" => info(&flags),
        "lint" => lint(&flags),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `onepiece help`"),
    }
}

fn artifacts_dir(flags: &HashMap<String, String>) -> PathBuf {
    flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let n_requests: usize = flags.get("requests").map_or(Ok(8), |s| s.parse())?;
    let steps: usize = flags.get("steps").map_or(Ok(4), |s| s.parse())?;
    let use_sim = flags.contains_key("sim");

    let mut cfg = ClusterConfig::i2v_default();
    cfg.fabric = onepiece::config::FabricKind::Ideal;

    let (pool, logic): (_, Arc<dyn onepiece::workflow::AppLogic>) = if use_sim {
        (build_pool(&cfg, None), Arc::new(onepiece::workflow::EchoLogic))
    } else {
        let rt = Arc::new(
            onepiece::runtime::PjrtRuntime::load(&artifacts_dir(flags))
                .context("loading PJRT artifacts (run `make artifacts`)")?,
        );
        println!("PJRT platform: {}", rt.platform());
        let vid_tokens = rt.manifest().dim("vid_tokens").unwrap_or(256) as usize;
        let d_latent = rt.manifest().dim("d_latent").unwrap_or(16) as usize;
        (
            build_pool(&cfg, Some(rt)),
            Arc::new(onepiece::workflow::I2vLogic::new(steps, vid_tokens, d_latent)),
        )
    };

    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    println!("instance plan per stage: {:?}", counts[0]);
    let set = WorkflowSet::build(cfg, counts, logic, pool);
    std::thread::sleep(Duration::from_millis(100));

    let image: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 11) as f32 / 11.0).collect();
    let tokens: Vec<f32> = (0..32).map(|i| ((i * 37) % 512) as f32).collect();
    let payload = Payload::Tensors(vec![
        ("tokens".into(), vec![32], tokens),
        ("image".into(), vec![32, 32, 3], image),
    ]);

    let mut handles = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        match set.submit(AppId(1), payload.clone()) {
            Ok(handle) => handles.push((i, handle, now_ns())),
            Err(e) => println!("request {i}: fast-rejected ({e})"),
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut latencies = Vec::new();
    for (i, handle, submitted) in &handles {
        match handle.wait(Duration::from_secs(120)) {
            WaitOutcome::Done(bytes) => {
                let lat_ms = (now_ns() - submitted) as f64 / 1e6;
                latencies.push(lat_ms);
                println!("request {i}: {} bytes in {:.1} ms", bytes.len(), lat_ms);
            }
            other => println!("request {i}: {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if !latencies.is_empty() {
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "\ncompleted {}/{} | throughput {:.2} req/s | p50 {:.1} ms | p99 {:.1} ms",
            latencies.len(),
            n_requests,
            latencies.len() as f64 / wall,
            latencies[latencies.len() / 2],
            latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)],
        );
    }
    set.shutdown();
    Ok(())
}

/// `onepiece federate`: N Workflow Sets behind the global load-aware
/// router, driven by a bursty MMPP arrival stream. Set 0 models a
/// heterogeneous (slower-GPU) region so cross-set donation has somewhere
/// to act: its diffusion executor runs slower than its siblings', its
/// utilization climbs, and the router moves idle-pool instances in.
fn federate(flags: &HashMap<String, String>) -> Result<()> {
    let config_path = flags.get("config").map(PathBuf::from);
    let rate: f64 = flags.get("rate").map_or(Ok(100.0), |s| s.parse())?;
    let duration_s: f64 = flags.get("duration").map_or(Ok(5.0), |s| s.parse())?;
    let kill_every_s: Option<f64> = flags.get("kill-every").map(|s| s.parse()).transpose()?;
    let fault_rate: Option<f64> = flags.get("fault-rate").map(|s| s.parse()).transpose()?;
    let partition_s: Option<f64> = flags.get("partition").map(|s| s.parse()).transpose()?;
    if !flags.contains_key("sim") {
        bail!(
            "`onepiece federate` requires --sim for now: PJRT-backed federation \
             needs `make artifacts` plus the `pjrt` feature"
        );
    }

    // Per-set config: entrance admission capped at 25 req/s
    // (exec_ms = 40 at 1 worker), instant simulated stage compute except
    // set 0's diffusion, which runs 30x slower than its siblings'. With
    // --config the file's shapes are taken as-is instead.
    let app = AppId(1);
    let mut base = match &config_path {
        Some(path) => ClusterConfig::from_file(path)
            .with_context(|| format!("loading cluster config {}", path.display()))?,
        None => {
            let mut cfg = ClusterConfig::i2v_default();
            cfg.fabric = onepiece::config::FabricKind::Ideal;
            for s in cfg.apps[0].stages.iter_mut() {
                s.exec = ExecModel::Simulated { ms: 1.0 };
            }
            cfg.apps[0].stages[0].exec_ms = 40.0;
            // This driver submits an SLO mix, so opt into the Interactive
            // admission reserve (10% of each set's budget).
            cfg.proxy.interactive_reserve = 0.1;
            cfg.idle_pool = 2;
            cfg
        }
    };
    let n_sets: usize = match flags.get("sets") {
        Some(s) => s.parse()?,
        None if config_path.is_some() => base.sets.max(1),
        None => 3,
    };
    if n_sets == 0 {
        bail!("--sets must be >= 1");
    }
    base.sets = n_sets;
    if flags.contains_key("cache") && base.cache.is_none() {
        base.cache = Some(onepiece::config::CacheSettings::default());
    }
    if let Some(secs) = kill_every_s {
        if secs <= 0.0 {
            bail!("--kill-every must be > 0 seconds");
        }
        // Chaos mode: the housekeeper kills an assigned instance on
        // this period; the failure detector (400 ms of heartbeat
        // silence) evicts and repairs it.
        base.chaos.kill_every_ms = (secs * 1000.0) as u64;
        base.chaos.seed = 42;
        base.nm.instance_timeout_ms = 400;
    }
    if let Some(p) = fault_rate {
        if !(0.0..=1.0).contains(&p) {
            bail!("--fault-rate must be in [0, 1]");
        }
        // Seeded verb loss on every set's fabric; the verb-retry layer
        // and Case 1-8 recovery absorb it (DESIGN.md §7).
        let mut faults = base.faults.take().unwrap_or_default();
        faults.verb_loss_prob = p;
        base.faults = Some(faults);
    }
    if let Some(secs) = partition_s {
        if secs <= 0.0 {
            bail!("--partition must be > 0 seconds");
        }
    }
    let cache_on = base.cache.is_some();
    let sets: Vec<WorkflowSet> = (0..n_sets)
        .map(|i| {
            let mut cfg = base.clone();
            if config_path.is_none() {
                let diffusion_ms = if i == 0 { 60.0 } else { 2.0 };
                cfg.apps[0].stages[2].exec = ExecModel::Simulated { ms: diffusion_ms };
            }
            let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
            WorkflowSet::build_standalone(
                cfg,
                counts,
                Arc::new(onepiece::workflow::EchoLogic),
                None,
            )
        })
        .collect();
    let fed = FederationRouter::new(sets, FederationConfig::default());
    std::thread::sleep(Duration::from_millis(100)); // assignments settle

    // Bursty offered load: MMPP alternating rate/4 and rate.
    let arrivals = ArrivalProcess::Mmpp {
        low_rps: rate / 4.0,
        high_rps: rate,
        mean_dwell_s: 1.0,
    }
    .generate(42, duration_s);
    println!(
        "federation: {n_sets} sets x 25 req/s admission capacity | offered MMPP \
         {:.0}-{rate:.0} req/s | {} arrivals over {duration_s}s",
        rate / 4.0,
        arrivals.len()
    );

    /// Move finished requests out of `pending`, recording latency at the
    /// moment the result is first observed (so reported latency is
    /// submission→completion, not submission→post-hoc drain). Deadline
    /// misses and cancellations are terminal too — they leave `pending`
    /// without contributing a latency sample.
    fn drain_finished(
        pending: &mut Vec<(RequestHandle, Instant)>,
        per_set_done: &mut [usize],
        latencies_ms: &mut Vec<f64>,
    ) {
        pending.retain(|(handle, submitted)| match handle.status() {
            RequestStatus::Done => {
                per_set_done[handle.set()] += 1;
                latencies_ms.push(submitted.elapsed().as_secs_f64() * 1e3);
                false
            }
            s => !s.is_terminal(),
        });
    }

    // SLO mix: one third of the traffic per priority class; Interactive
    // carries a 2 s end-to-end deadline (missed deadlines surface in the
    // per-set `deadline_missed` counters below). Under chaos, every
    // class carries a 3-attempt retry policy — that budget is what the
    // recovery sweep spends replaying requests stranded on killed
    // instances.
    let retry = if kill_every_s.is_some() {
        RetryPolicy::attempts(3, Duration::ZERO)
    } else {
        RetryPolicy::default()
    };
    let slo_mix = [
        SubmitOptions::interactive()
            .with_deadline(Duration::from_secs(2))
            .with_retry(retry),
        SubmitOptions::default().with_retry(retry),
        SubmitOptions::batch().with_retry(retry),
    ];
    // With the cache on, prompts are drawn Zipf-distributed over 16
    // distinct values — repeats are what the cache exploits. Uncached
    // runs keep the original constant payload.
    let zipf = onepiece::sim::Zipf::new(16, 1.0);
    let mut prompt_rng = onepiece::util::Rng::new(7);
    let t0 = Instant::now();
    let mut pending: Vec<(RequestHandle, Instant)> = Vec::new();
    let mut per_set_done = vec![0usize; n_sets];
    let mut latencies_ms: Vec<f64> = Vec::new();
    let mut admitted_total = 0usize;
    let mut next_rebalance = 0.25f64;
    // Directed partition: cut a node pair on set 0's fabric a third of
    // the way into the run, heal it --partition seconds later.
    let mut partition_at = partition_s.map(|_| (duration_s / 3.0).max(0.05));
    let mut heal_at: Option<f64> = None;
    for (i, &arr) in arrivals.iter().enumerate() {
        let target = t0 + Duration::from_secs_f64(arr);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        // Catch up the timer through idle gaps (sparse arrivals must not
        // leave the schedule permanently behind).
        while arr >= next_rebalance {
            if let Some(d) = fed.rebalance(app) {
                println!(
                    "  [t={arr:.2}s] donation: set {} -> set {} ({} retired, {} joined)",
                    d.from_set, d.to_set, d.retired, d.spawned
                );
            }
            // Breaker scan on the same cadence: open/half-open counts
            // drive the proxies' brownout shed level.
            fed.refresh_brownout();
            next_rebalance += 0.25;
        }
        if partition_at.is_some_and(|t| arr >= t) {
            fed.with_set(0, |s| s.fabric.start_partition(4, 1));
            println!("  [t={arr:.2}s] partition: set 0 node pair cut");
            heal_at = partition_s.map(|secs| arr + secs);
            partition_at = None;
        }
        if heal_at.is_some_and(|t| arr >= t) {
            fed.with_set(0, |s| s.fabric.heal_partition());
            println!("  [t={arr:.2}s] partition: healed");
            heal_at = None;
        }
        let payload = if cache_on {
            Payload::Bytes(vec![zipf.sample(&mut prompt_rng) as u8; 64])
        } else {
            Payload::Bytes(vec![7u8; 64])
        };
        if let Ok(handle) = fed.submit_with(app, payload, slo_mix[i % 3]) {
            admitted_total += 1;
            pending.push((handle, Instant::now()));
        }
        drain_finished(&mut pending, &mut per_set_done, &mut latencies_ms);
    }

    // A partition that outlives the arrival stream is healed here so the
    // backlog can drain through the repaired fabric.
    if heal_at.take().is_some() {
        fed.with_set(0, |s| s.fabric.heal_partition());
        println!("  [drain] partition: healed");
    }

    // Drain the backlog (set 0's slow diffusion keeps a queue).
    let drain_deadline = Instant::now() + Duration::from_secs(15);
    while !pending.is_empty() && Instant::now() < drain_deadline {
        drain_finished(&mut pending, &mut per_set_done, &mut latencies_ms);
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let counters: HashMap<String, u64> =
        fed.metrics().counters_snapshot().into_iter().collect();
    let get = |k: &str| counters.get(k).copied().unwrap_or(0);
    let snaps = fed.snapshots(app);
    println!(
        "\n{:<6} {:>9} {:>10} {:>12} {:>10} {:>10} {:>6}",
        "set", "accepted", "completed", "thr (req/s)", "spill-in", "util", "idle"
    );
    for s in &snaps {
        let acc = get(&format!("fed.set{}.accepted", s.set));
        println!(
            "{:<6} {:>9} {:>10} {:>12.1} {:>10} {:>9.1}% {:>6}",
            format!("set{}", s.set),
            acc,
            per_set_done[s.set],
            per_set_done[s.set] as f64 / wall,
            get(&format!("fed.set{}.spill_in", s.set)),
            s.max_stage_util * 100.0,
            s.idle_instances,
        );
    }
    let submitted = get("fed.submitted");
    let rejected = get("fed.rejected");
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\ntotals: submitted {submitted} | accepted {} | spilled {} | rejected \
         {rejected} ({:.1}% reject rate) | donations {}",
        get("fed.accepted"),
        get("fed.spilled"),
        100.0 * rejected as f64 / submitted.max(1) as f64,
        get("fed.donations"),
    );

    // Result-lifecycle metrics: per-priority admission at the federation
    // tier, cancellation / deadline-miss counts summed over the member
    // sets' registries (where the tracker and proxies account them).
    let mut set_totals: HashMap<String, u64> = HashMap::new();
    for i in 0..n_sets {
        for (k, v) in fed.with_set(i, |s| {
            s.sync_fault_counters();
            s.metrics().counters_snapshot()
        }) {
            *set_totals.entry(k).or_insert(0) += v;
        }
    }
    let set_get = |k: &str| set_totals.get(k).copied().unwrap_or(0);
    println!("\n{:<13} {:>9} {:>9}", "priority", "accepted", "rejected");
    for p in Priority::ALL {
        println!(
            "{:<13} {:>9} {:>9}",
            p.label(),
            get(&format!("fed.accepted.{}", p.label())),
            get(&format!("fed.rejected.{}", p.label())),
        );
    }
    println!(
        "lifecycle: requests_cancelled {} | deadline_missed {} (Interactive carries a 2 s deadline)",
        set_get("requests_cancelled"),
        set_get("deadline_missed"),
    );
    if cache_on {
        let prefix_sum = |prefix: &str| -> u64 {
            set_totals
                .iter()
                .filter(|(k, _)| k.starts_with(prefix))
                .map(|(_, v)| *v)
                .sum()
        };
        println!(
            "cache: hits {} | misses {} | coalesced {} | evictions {} | \
             bytes_saved {} | warm_reads {}",
            prefix_sum("cache_hits."),
            prefix_sum("cache_misses."),
            set_get("cache_coalesced_total"),
            set_get("cache_evictions_total"),
            set_get("cache_bytes_saved_total"),
            set_get("cache_warm_reads_total"),
        );
    }
    if kill_every_s.is_some() {
        println!(
            "chaos: kills {} | instances_failed {} | instances_replaced {} | \
             requests_recovered {} | requests_failed {}",
            set_get("chaos_kills"),
            set_get("instances_failed"),
            set_get("instances_replaced"),
            set_get("requests_recovered"),
            set_get("requests_failed"),
        );
    }
    if fault_rate.is_some() || partition_s.is_some() {
        let states = fed.breaker_states();
        let opens: u64 = (0..n_sets)
            .map(|i| get(&format!("fed.set{i}.breaker_open_total")))
            .sum();
        println!(
            "breaker: states [{}] | opens {opens} | brownout_level {}",
            states.join(", "),
            fed.refresh_brownout(),
        );
        println!(
            "faults: verbs_lost {} | verbs_delayed {} | region_flaps {} | \
             partitioned_ops {} | verb_retries {} | shed interactive {} \
             standard {} batch {}",
            set_get("verbs_lost_total"),
            set_get("verbs_delayed_total"),
            set_get("region_flaps_total"),
            set_get("partitioned_ops_total"),
            set_get("verb_retries_total"),
            set_get("requests_shed.interactive"),
            set_get("requests_shed.standard"),
            set_get("requests_shed.batch"),
        );
    }
    println!(
        "latency: completed {}/{} | p50 {:.1} ms | p99 {:.1} ms | wall {wall:.1}s",
        latencies_ms.len(),
        admitted_total,
        onepiece::sim::percentile(&latencies_ms, 0.5),
        onepiece::sim::percentile(&latencies_ms, 0.99),
    );
    fed.shutdown();
    Ok(())
}

fn plan(flags: &HashMap<String, String>) -> Result<()> {
    let entrance: usize = flags.get("entrance").map_or(Ok(1), |s| s.parse())?;
    let cfg = ClusterConfig::i2v_default();
    let reqs: Vec<onepiece::pipeline::StageReq> = cfg.apps[0]
        .stages
        .iter()
        .map(|s| onepiece::pipeline::StageReq {
            name: s.name.clone(),
            exec_s: s.exec_ms / 1000.0,
            gpus_per_instance: s.gpus_per_instance,
            workers: s.workers,
        })
        .collect();
    let plan = onepiece::pipeline::plan_chain(&reqs, entrance);
    println!("{:<16} {:>9} {:>6} {:>12}", "stage", "instances", "gpus", "rate(req/s)");
    for s in &plan.stages {
        println!("{:<16} {:>9} {:>6} {:>12.2}", s.name, s.instances, s.gpus, s.rate);
    }
    println!(
        "\noutput every {:.3} s | request latency {:.3} s | total {} GPUs",
        plan.output_interval_s, plan.request_latency_s, plan.total_gpus
    );
    Ok(())
}

fn trace(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("config") {
        return trace_live(flags);
    }
    let (stages, admit) = if flags.contains_key("fig6") {
        (
            vec![
                TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 2 },
                TraceStage { name: "Y".into(), exec_s: 12.0, instances: 6, workers: 1 },
            ],
            2.0,
        )
    } else {
        (
            vec![
                TraceStage { name: "X".into(), exec_s: 4.0, instances: 1, workers: 1 },
                TraceStage { name: "Y".into(), exec_s: 12.0, instances: 3, workers: 1 },
            ],
            4.0,
        )
    };
    let t = trace_schedule(&stages, 8, admit);
    println!("{}", t.render_gantt(&stages, admit.min(4.0)));
    println!("steady-state output interval: {:.1} s", t.output_interval_s);
    Ok(())
}

/// `onepiece trace --config PATH`: run a traced Workflow Set against a
/// short workload and print per-stage queue/exec/transit percentiles
/// plus exemplar slow traces with their critical paths.
fn trace_live(flags: &HashMap<String, String>) -> Result<()> {
    let path = PathBuf::from(flags.get("config").unwrap());
    let n_requests: usize = flags.get("requests").map_or(Ok(24), |s| s.parse())?;
    let json_out = flags.contains_key("json");
    let mut cfg = ClusterConfig::from_file(&path)
        .with_context(|| format!("loading cluster config {}", path.display()))?;
    if cfg.trace.is_none() {
        // The whole point of this command is to look at traces: keep
        // everything unless the config says otherwise.
        cfg.trace = Some(onepiece::config::TraceSettings::default());
    }
    let app = AppId(cfg.apps[0].id);
    let stage_names: Vec<String> =
        cfg.apps[0].stages.iter().map(|s| s.name.clone()).collect();
    let pool = build_pool(&cfg, None);
    let counts = vec![WorkflowSet::theorem1_counts(&cfg.apps[0], 1)];
    let set = WorkflowSet::build(
        cfg,
        counts,
        Arc::new(onepiece::workflow::EchoLogic),
        pool,
    );
    std::thread::sleep(Duration::from_millis(100)); // assignments settle

    let mut handles = Vec::new();
    for i in 0..n_requests {
        // Distinct payloads so a configured artifact cache doesn't
        // collapse the workload into one executed request.
        let payload = Payload::Bytes(vec![(i % 251) as u8; 64 + i]);
        match set.submit(app, payload) {
            Ok(h) => handles.push(h),
            Err(e) => println!("request {i}: fast-rejected ({e})"),
        }
    }
    for h in &handles {
        if !matches!(h.wait(Duration::from_secs(30)), WaitOutcome::Done(_)) {
            println!("request {:?} did not complete", h.uid());
        }
    }
    let tracer = set
        .tracer()
        .expect("trace block is present, so the set has a tracer");
    let traces = tracer.completed();
    if traces.is_empty() {
        bail!(
            "no traces kept ({} requests ran) — raise trace.sample_rate or \
             trace.always_sample_slow_ms in {}",
            handles.len(),
            path.display()
        );
    }

    // Per-stage queue/exec/transit samples across every kept trace.
    let n_stages = stage_names.len();
    let mut queue = vec![Vec::new(); n_stages];
    let mut exec = vec![Vec::new(); n_stages];
    let mut transit = vec![Vec::new(); n_stages];
    let mut totals: Vec<f64> = Vec::new();
    for t in &traces {
        totals.push(t.total_ns as f64 / 1e6);
        for b in t.breakdown() {
            let s = b.stage as usize;
            if s < n_stages {
                queue[s].push(b.queue_ns as f64 / 1e6);
                exec[s].push(b.exec_ns as f64 / 1e6);
                transit[s].push(b.transit_ns as f64 / 1e6);
            }
        }
    }
    for v in queue.iter_mut().chain(exec.iter_mut()).chain(transit.iter_mut()) {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    totals.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let pct = |v: &[f64]| {
        (
            onepiece::sim::percentile(v, 0.5),
            onepiece::sim::percentile(v, 0.95),
            onepiece::sim::percentile(v, 0.99),
        )
    };
    println!(
        "traced {} of {} requests | total p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        traces.len(),
        handles.len(),
        onepiece::sim::percentile(&totals, 0.5),
        onepiece::sim::percentile(&totals, 0.95),
        onepiece::sim::percentile(&totals, 0.99),
    );
    println!(
        "\n{:<16} {:>8} {:>8} {:>8}   {:>8} {:>8} {:>8}   {:>8} {:>8} {:>8}",
        "stage (ms)", "q p50", "q p95", "q p99", "ex p50", "ex p95", "ex p99",
        "tr p50", "tr p95", "tr p99"
    );
    for (s, name) in stage_names.iter().enumerate() {
        if queue[s].is_empty() && exec[s].is_empty() && transit[s].is_empty() {
            continue;
        }
        let (q50, q95, q99) = pct(&queue[s]);
        let (e50, e95, e99) = pct(&exec[s]);
        let (t50, t95, t99) = pct(&transit[s]);
        println!(
            "{name:<16} {q50:>8.3} {q95:>8.3} {q99:>8.3}   {e50:>8.3} {e95:>8.3} \
             {e99:>8.3}   {t50:>8.3} {t95:>8.3} {t99:>8.3}"
        );
    }

    // Exemplar slow traces: the tail the breakdown table averages away.
    let mut by_slowest: Vec<&onepiece::trace::Trace> = traces.iter().collect();
    by_slowest.sort_by_key(|t| std::cmp::Reverse(t.total_ns));
    println!("\nslowest traces:");
    for t in by_slowest.iter().take(3) {
        let verdict = t.verdict.map_or("partial", |v| v.label());
        let stage_path: Vec<String> = t
            .stage_path()
            .iter()
            .map(|&s| {
                stage_names
                    .get(s as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("s{s}"))
            })
            .collect();
        println!(
            "  {:?} {:.2} ms [{}] via {}",
            t.uid,
            t.total_ns as f64 / 1e6,
            verdict,
            stage_path.join(" -> "),
        );
        let segs: Vec<String> = t
            .critical_path()
            .iter()
            .map(|(name, ns)| format!("{name} {:.2} ms", *ns as f64 / 1e6))
            .collect();
        println!("    critical path: {}", segs.join(" | "));
    }

    if json_out {
        use onepiece::util::Json;
        use std::collections::BTreeMap;
        let triple = |v: &[f64]| {
            let (p50, p95, p99) = pct(v);
            let mut m = BTreeMap::new();
            m.insert("p50_ms".to_string(), Json::Num(p50));
            m.insert("p95_ms".to_string(), Json::Num(p95));
            m.insert("p99_ms".to_string(), Json::Num(p99));
            Json::Obj(m)
        };
        let stages_json: Vec<Json> = stage_names
            .iter()
            .enumerate()
            .map(|(s, name)| {
                let mut m = BTreeMap::new();
                m.insert("stage".to_string(), Json::Str(name.clone()));
                m.insert("queue".to_string(), triple(&queue[s]));
                m.insert("exec".to_string(), triple(&exec[s]));
                m.insert("transit".to_string(), triple(&transit[s]));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("traced".to_string(), Json::Num(traces.len() as f64));
        root.insert("requests".to_string(), Json::Num(handles.len() as f64));
        root.insert("total".to_string(), triple(&totals));
        root.insert("stages".to_string(), Json::Arr(stages_json));
        println!("\n{}", Json::Obj(root).to_string_compact());
    }
    set.shutdown();
    Ok(())
}

fn sim_resources(flags: &HashMap<String, String>) -> Result<()> {
    let peak: f64 = flags.get("peak").map_or(Ok(1.0), |s| s.parse())?;
    let pattern = flags.get("pattern").map(String::as_str).unwrap_or("diurnal");
    let process = match pattern {
        "poisson" => ArrivalProcess::Poisson { rate_rps: peak },
        "mmpp" => ArrivalProcess::Mmpp {
            low_rps: peak / 10.0,
            high_rps: peak,
            mean_dwell_s: 60.0,
        },
        "diurnal" => ArrivalProcess::Diurnal {
            base_rps: peak / 16.0,
            peak_rps: peak,
            period_s: 600.0,
        },
        other => bail!("unknown pattern {other:?}"),
    };
    let cfg = ResourceSimConfig {
        stages: wan_stages(),
        monolithic_gpus: 8,
        rescale_period_s: 10.0,
        demand_window_s: 30.0,
        duration_s: 1200.0,
    };
    let mono = simulate_monolithic(&cfg, &process, 42);
    let dis = simulate_disaggregated(&cfg, &process, 42);
    println!("pattern={pattern} peak={peak} req/s duration={}s", cfg.duration_s);
    println!(
        "{:<14} {:>12} {:>12} {:>10} {:>10} {:>8}",
        "fleet", "gpu-s prov", "gpu-s busy", "util", "p99 (s)", "done"
    );
    for (name, o) in [("monolithic", mono), ("onepiece", dis)] {
        println!(
            "{:<14} {:>12.0} {:>12.0} {:>9.1}% {:>10.1} {:>8}",
            name,
            o.gpu_s_provisioned,
            o.gpu_s_busy,
            o.utilization * 100.0,
            o.p99_latency_s,
            o.completed
        );
    }
    println!(
        "\nGPU-resource reduction: {:.1}x (paper claims 16x for Wan2.1 I2V)",
        mono.gpu_s_provisioned / dis.gpu_s_provisioned
    );
    Ok(())
}

fn info(flags: &HashMap<String, String>) -> Result<()> {
    let dir = artifacts_dir(flags);
    let manifest = onepiece::runtime::Manifest::load(&dir.join("manifest.json"))?;
    println!("artifacts: {}", dir.display());
    println!("dims: {:?}", manifest.dims);
    for (name, s) in &manifest.stages {
        let inputs: Vec<String> = s
            .inputs
            .iter()
            .map(|i| format!("{}:{:?}", i.name, i.shape))
            .collect();
        println!("  {name}: [{}] -> {:?} ({})", inputs.join(", "), s.output.shape, s.file);
    }
    let rt = onepiece::runtime::PjrtRuntime::load_stages(&dir, &["vae_encode"])?;
    println!("PJRT platform: {}", rt.platform());
    Ok(())
}

fn lint(flags: &HashMap<String, String>) -> Result<()> {
    let src = PathBuf::from(
        flags
            .get("src")
            .map(String::as_str)
            .unwrap_or("rust/src"),
    );
    if !src.is_dir() {
        bail!(
            "lint: source root {src:?} is not a directory (run from the repo \
             root or pass --src)"
        );
    }
    let baseline_path = PathBuf::from(
        flags
            .get("baseline")
            .map(String::as_str)
            .unwrap_or("LINT_BASELINE.json"),
    );
    let baseline_set = onepiece::lint::load_baseline(&baseline_path)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let outcome = onepiece::lint::lint_tree(&src, &baseline_set)
        .with_context(|| format!("scanning {src:?}"))?;

    if flags.contains_key("write-baseline") {
        let text = onepiece::lint::baseline::render(&outcome.violations);
        std::fs::write(&baseline_path, text)
            .with_context(|| format!("writing {baseline_path:?}"))?;
        println!(
            "lint: wrote {} fingerprints to {}",
            outcome.violations.len(),
            baseline_path.display()
        );
    }

    for v in &outcome.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.message);
    }
    let report_path = PathBuf::from(
        flags
            .get("json")
            .map(String::as_str)
            .unwrap_or("LINT_REPORT.json"),
    );
    std::fs::write(&report_path, outcome.to_json().to_string_compact())
        .with_context(|| format!("writing {report_path:?}"))?;
    println!("{}", outcome.summary());
    if !outcome.violations.is_empty() && !flags.contains_key("write-baseline") {
        bail!("lint failed with {} violations", outcome.violations.len());
    }
    Ok(())
}
