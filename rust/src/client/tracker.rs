//! The [`RequestTracker`]: the control plane's per-UID request-lifecycle
//! state (priority, absolute deadline, cancellation flag, current stage).
//!
//! The proxy registers every admitted request here; the workflow data
//! plane (RequestScheduler / TaskWorkers, §4.3–§4.5) consults
//! [`RequestTracker::verdict`] before spending compute on a message and
//! drops work whose request was cancelled or whose deadline passed —
//! publishing a tombstone to the database layer instead of a result —
//! and [`crate::client::RequestHandle`] reads the same state to report
//! typed [`crate::client::RequestStatus`] to callers.
//!
//! Keeping SLO state in the control plane (rather than widening the §4.1
//! wire header) means the RDMA hot path carries exactly the paper's
//! message format while priorities and deadlines still reach every stage
//! of the pipeline.

use crate::client::Priority;
use crate::metrics::{Counter, Registry};
use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// What the data plane should do with an in-flight message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InFlightVerdict {
    /// Keep processing.
    Proceed,
    /// The client cancelled: drop the work.
    Cancelled,
    /// The request's deadline passed: drop the work, publish a
    /// `DeadlineExceeded` tombstone.
    DeadlineExceeded,
}

/// Handle-facing probe of a tracked request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrackedState {
    /// Never registered, or already finished and removed.
    Unknown,
    /// In flight; `stage` is the last stage a worker reported, `None`
    /// until the entrance stage picks it up.
    InFlight { stage: Option<u32> },
    Cancelled,
    DeadlineExceeded,
}

struct Entry {
    priority: Priority,
    /// Absolute deadline on the tracker's clock, if any.
    deadline_ns: Option<u64>,
    cancelled: bool,
    stage: Option<u32>,
    registered_ns: u64,
    /// Guards the `deadline_missed` counter (count each UID once).
    deadline_counted: bool,
}

/// Shared per-set request-lifecycle registry.
pub struct RequestTracker {
    clock: Arc<dyn Clock>,
    metrics: Registry,
    cancelled_ctr: Arc<Counter>,
    deadline_ctr: Arc<Counter>,
    inner: Mutex<HashMap<Uid, Entry>>,
}

impl RequestTracker {
    pub fn new(clock: Arc<dyn Clock>, metrics: Registry) -> Self {
        let cancelled_ctr = metrics.counter("requests_cancelled");
        let deadline_ctr = metrics.counter("deadline_missed");
        Self {
            clock,
            metrics,
            cancelled_ctr,
            deadline_ctr,
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// The registry the tracker counts `requests_cancelled` /
    /// `deadline_missed` into (shared with the owning set's proxy).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Track a freshly admitted request. `deadline` is relative to now.
    pub fn register(&self, uid: Uid, priority: Priority, deadline: Option<Duration>) {
        let now = self.clock.now_ns();
        let entry = Entry {
            priority,
            deadline_ns: deadline.map(|d| now.saturating_add(d.as_nanos() as u64)),
            cancelled: false,
            stage: None,
            registered_ns: now,
            deadline_counted: false,
        };
        self.inner.lock().unwrap().insert(uid, entry);
    }

    /// Scheduling priority of a tracked request (Standard if unknown —
    /// e.g. the entry aged out of the tracker).
    pub fn priority_of(&self, uid: Uid) -> Priority {
        self.inner
            .lock()
            .unwrap()
            .get(&uid)
            .map(|e| e.priority)
            .unwrap_or(Priority::Standard)
    }

    /// A worker reports that `uid` is executing at `stage`.
    pub fn note_stage(&self, uid: Uid, stage: u32) {
        if let Some(e) = self.inner.lock().unwrap().get_mut(&uid) {
            e.stage = Some(e.stage.map_or(stage, |s| s.max(stage)));
        }
    }

    /// Mark a request cancelled. Returns `true` when this call newly
    /// cancelled it (false if it was already cancelled). Unknown UIDs get
    /// a synthetic cancelled entry so late-arriving messages still drop.
    pub fn cancel(&self, uid: Uid) -> bool {
        let mut g = self.inner.lock().unwrap();
        let newly = match g.get_mut(&uid) {
            Some(e) => {
                let newly = !e.cancelled;
                e.cancelled = true;
                newly
            }
            None => {
                g.insert(
                    uid,
                    Entry {
                        priority: Priority::Standard,
                        deadline_ns: None,
                        cancelled: true,
                        stage: None,
                        registered_ns: self.clock.now_ns(),
                        deadline_counted: false,
                    },
                );
                true
            }
        };
        if newly {
            self.cancelled_ctr.inc();
        }
        newly
    }

    /// Data-plane check: should work on `uid` continue? Counts the first
    /// deadline detection into `deadline_missed`.
    pub fn verdict(&self, uid: Uid) -> InFlightVerdict {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else {
            return InFlightVerdict::Proceed;
        };
        if e.cancelled {
            return InFlightVerdict::Cancelled;
        }
        if e.deadline_ns.is_some_and(|d| now > d) {
            if !e.deadline_counted {
                e.deadline_counted = true;
                self.deadline_ctr.inc();
            }
            return InFlightVerdict::DeadlineExceeded;
        }
        InFlightVerdict::Proceed
    }

    /// Handle-facing probe (same deadline accounting as
    /// [`RequestTracker::verdict`], plus stage progress).
    pub fn probe(&self, uid: Uid) -> TrackedState {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else {
            return TrackedState::Unknown;
        };
        if e.cancelled {
            return TrackedState::Cancelled;
        }
        if e.deadline_ns.is_some_and(|d| now > d) {
            if !e.deadline_counted {
                e.deadline_counted = true;
                self.deadline_ctr.inc();
            }
            return TrackedState::DeadlineExceeded;
        }
        TrackedState::InFlight { stage: e.stage }
    }

    /// Drop a request's entry (terminal state reached: the result/
    /// tombstone is in the DB, or the handle consumed it).
    pub fn finish(&self, uid: Uid) {
        self.inner.lock().unwrap().remove(&uid);
    }

    /// Tracked request count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when no requests are tracked.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop entries older than `max_age_ns` (lost requests — e.g. §9
    /// message loss — would otherwise leak their entry forever). Run by
    /// the set's housekeeping timer with the DB TTL. Returns how many
    /// entries were purged.
    pub fn purge_older_than(&self, max_age_ns: u64) -> usize {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let before = g.len();
        g.retain(|_, e| now.saturating_sub(e.registered_ns) <= max_age_ns);
        before - g.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup() -> (ManualClock, RequestTracker) {
        let c = ManualClock::new();
        c.set(1);
        let t = RequestTracker::new(Arc::new(c.clone()), Registry::new());
        (c, t)
    }

    fn uid(i: u32) -> Uid {
        Uid::fresh(NodeId(i))
    }

    #[test]
    fn register_and_proceed() {
        let (_c, t) = setup();
        let u = uid(1);
        t.register(u, Priority::Interactive, None);
        assert_eq!(t.verdict(u), InFlightVerdict::Proceed);
        assert_eq!(t.priority_of(u), Priority::Interactive);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: None });
        t.note_stage(u, 2);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: Some(2) });
        // Stage progress is monotone (a late entrance report can't rewind).
        t.note_stage(u, 1);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: Some(2) });
    }

    #[test]
    fn unknown_uid_proceeds() {
        let (_c, t) = setup();
        assert_eq!(t.verdict(uid(9)), InFlightVerdict::Proceed);
        assert_eq!(t.probe(uid(9)), TrackedState::Unknown);
        assert_eq!(t.priority_of(uid(9)), Priority::Standard);
    }

    #[test]
    fn cancel_marks_and_counts_once() {
        let (_c, t) = setup();
        let u = uid(2);
        t.register(u, Priority::Standard, None);
        assert!(t.cancel(u));
        assert!(!t.cancel(u), "second cancel is a no-op");
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
        assert_eq!(t.metrics().counter("requests_cancelled").get(), 1);
    }

    #[test]
    fn cancel_unknown_uid_drops_late_messages() {
        let (_c, t) = setup();
        let u = uid(3);
        assert!(t.cancel(u));
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
    }

    #[test]
    fn deadline_expires_and_counts_once() {
        let (c, t) = setup();
        let u = uid(4);
        t.register(u, Priority::Batch, Some(Duration::from_millis(10)));
        assert_eq!(t.verdict(u), InFlightVerdict::Proceed);
        c.advance(10_000_001);
        assert_eq!(t.verdict(u), InFlightVerdict::DeadlineExceeded);
        assert_eq!(t.verdict(u), InFlightVerdict::DeadlineExceeded);
        assert_eq!(t.probe(u), TrackedState::DeadlineExceeded);
        assert_eq!(t.metrics().counter("deadline_missed").get(), 1);
    }

    #[test]
    fn cancellation_beats_deadline() {
        let (c, t) = setup();
        let u = uid(5);
        t.register(u, Priority::Standard, Some(Duration::from_millis(1)));
        t.cancel(u);
        c.advance(10_000_000);
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
    }

    #[test]
    fn finish_removes_and_purge_sweeps() {
        let (c, t) = setup();
        let a = uid(6);
        let b = uid(7);
        t.register(a, Priority::Standard, None);
        c.advance(1_000_000);
        t.register(b, Priority::Standard, None);
        assert_eq!(t.len(), 2);
        t.finish(a);
        assert_eq!(t.len(), 1);
        c.advance(10_000_000);
        // b is now ~10 ms old; purge anything older than 5 ms.
        assert_eq!(t.purge_older_than(5_000_000), 1);
        assert!(t.is_empty());
    }
}
