//! The [`RequestTracker`]: the control plane's per-UID request-lifecycle
//! state (priority, absolute deadline, cancellation flag, current stage).
//!
//! The proxy registers every admitted request here; the workflow data
//! plane (RequestScheduler / TaskWorkers, §4.3–§4.5) consults
//! [`RequestTracker::verdict`] before spending compute on a message and
//! drops work whose request was cancelled or whose deadline passed —
//! publishing a tombstone to the database layer instead of a result —
//! and [`crate::client::RequestHandle`] reads the same state to report
//! typed [`crate::client::RequestStatus`] to callers.
//!
//! Keeping SLO state in the control plane (rather than widening the §4.1
//! wire header) means the RDMA hot path carries exactly the paper's
//! message format while priorities and deadlines still reach every stage
//! of the pipeline.

use crate::client::{Priority, SubmitOptions};
use crate::lint::runtime::{WitnessMutex, RANK_TRACKER};
use crate::metrics::{Counter, Registry};
use crate::rdma::RegionId;
use crate::util::{Clock, Uid};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// What the data plane should do with an in-flight message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InFlightVerdict {
    /// Keep processing.
    Proceed,
    /// The client cancelled: drop the work.
    Cancelled,
    /// The request's deadline passed: drop the work, publish a
    /// `DeadlineExceeded` tombstone.
    DeadlineExceeded,
    /// The request was declared unrecoverable (instance failure with
    /// recovery retries exhausted): drop the work, publish a `Failed`
    /// tombstone.
    Failed,
}

/// Handle-facing probe of a tracked request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrackedState {
    /// Never registered, or already finished and removed.
    Unknown,
    /// In flight; `stage` is the last stage a worker reported, `None`
    /// until the entrance stage picks it up.
    InFlight { stage: Option<u32> },
    Cancelled,
    DeadlineExceeded,
    /// Lost to an instance failure; recovery exhausted.
    Failed,
}

/// Outcome of [`RequestTracker::begin_replay`] — what the recovery
/// sweep should do with a request stranded on a dead instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplayVerdict {
    /// Budget consumed: replay the checkpoint now.
    Replay,
    /// No replay budget left (the gateway's `RetryPolicy` bounds total
    /// execution attempts): the request was marked failed; publish the
    /// `Failed` tombstone.
    Exhausted,
    /// Already terminal (cancelled / failed / deadline passed /
    /// untracked): nothing to do.
    Terminal,
}

struct Entry {
    priority: Priority,
    /// Absolute deadline on the tracker's clock, if any.
    deadline_ns: Option<u64>,
    cancelled: bool,
    failed: bool,
    /// Flagged for the recovery sweep to replay from checkpoint: the
    /// data plane holds (held) a message it can no longer progress —
    /// e.g. its instance's role changed mid-queue during a donor steal.
    stranded: bool,
    stage: Option<u32>,
    /// Ring region the request was last sent to (proxy forward or RD
    /// next-hop) — the recovery sweep uses it to find the in-flight
    /// requests assigned to a dead instance.
    location: Option<RegionId>,
    /// Remaining recovery replays (from the submit `RetryPolicy`:
    /// `max_attempts` bounds total execution attempts, the original
    /// dispatch included).
    replays_left: u32,
    registered_ns: u64,
    /// Guards the `deadline_missed` counter (count each UID once).
    deadline_counted: bool,
}

impl Entry {
    /// Cancelled, failed, or past its deadline: no replay, no strand,
    /// and no further terminal transition may overwrite it. The single
    /// gate shared by `begin_replay` / `strand` / `mark_failed` /
    /// `uids_at` so their terminal semantics cannot drift apart.
    fn is_terminal(&self, now_ns: u64) -> bool {
        self.cancelled || self.failed || self.deadline_ns.is_some_and(|d| now_ns > d)
    }
}

/// Shared per-set request-lifecycle registry.
pub struct RequestTracker {
    clock: Arc<dyn Clock>,
    metrics: Registry,
    cancelled_ctr: Arc<Counter>,
    deadline_ctr: Arc<Counter>,
    failed_ctr: Arc<Counter>,
    /// Trace hook for terminal-verdict events (set once at build when
    /// tracing is on; recording is lock-free so it is safe under the
    /// tracker lock).
    trace: std::sync::OnceLock<crate::trace::TraceHook>,
    inner: WitnessMutex<HashMap<Uid, Entry>>, // lint: lock-rank(tracker, 40)
}

impl RequestTracker {
    pub fn new(clock: Arc<dyn Clock>, metrics: Registry) -> Self {
        let cancelled_ctr = metrics.counter("requests_cancelled");
        let deadline_ctr = metrics.counter("deadline_missed");
        let failed_ctr = metrics.counter("requests_failed");
        Self {
            clock,
            metrics,
            cancelled_ctr,
            deadline_ctr,
            failed_ctr,
            trace: std::sync::OnceLock::new(),
            inner: WitnessMutex::new("tracker", RANK_TRACKER, HashMap::new()),
        }
    }

    /// Attach the set's trace hook (build-time wiring, set once): the
    /// tracker then records `Terminal{Cancelled|DeadlineExceeded|Failed}`
    /// events as those verdicts are first reached.
    pub fn set_trace(&self, hook: crate::trace::TraceHook) {
        let _ = self.trace.set(hook);
    }

    /// Record a terminal verdict event for `uid` (first transition only;
    /// call sites guard with their own newly-terminal checks).
    fn trace_terminal(&self, uid: Uid, stage: Option<u32>, verdict: crate::trace::Verdict) {
        if let Some(h) = self.trace.get() {
            h.record(uid, stage, crate::trace::EventKind::Terminal { verdict });
        }
    }

    /// The registry the tracker counts `requests_cancelled` /
    /// `deadline_missed` into (shared with the owning set's proxy).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Track a freshly admitted request. `deadline` is relative to now;
    /// `replays` is the recovery budget (how many times a crash may
    /// replay this request before it is declared `Failed`).
    pub fn register_full(
        &self,
        uid: Uid,
        priority: Priority,
        deadline: Option<Duration>,
        replays: u32,
    ) {
        let now = self.clock.now_ns();
        let entry = Entry {
            priority,
            deadline_ns: deadline.map(|d| now.saturating_add(d.as_nanos() as u64)),
            cancelled: false,
            failed: false,
            stranded: false,
            stage: None,
            location: None,
            replays_left: replays,
            registered_ns: now,
            deadline_counted: false,
        };
        self.inner.lock().unwrap().insert(uid, entry);
    }

    /// Track an admitted request with its submit options: the
    /// `RetryPolicy`'s `max_attempts` bounds total execution attempts,
    /// so the recovery budget is `max_attempts - 1` replays.
    pub fn register_with(&self, uid: Uid, opts: &SubmitOptions) {
        self.register_full(
            uid,
            opts.priority,
            opts.deadline,
            opts.retry.max_attempts.saturating_sub(1),
        );
    }

    /// Track a freshly admitted request with no recovery budget (tests
    /// and legacy callers).
    pub fn register(&self, uid: Uid, priority: Priority, deadline: Option<Duration>) {
        self.register_full(uid, priority, deadline, 0);
    }

    /// Record where `uid` was last sent (proxy entrance forward or RD
    /// instance hop). The recovery sweep reads this back through
    /// [`RequestTracker::uids_at`] when that ring's owner dies.
    pub fn note_location(&self, uid: Uid, region: RegionId) {
        if let Some(e) = self.inner.lock().unwrap().get_mut(&uid) {
            e.location = Some(region);
        }
    }

    /// In-flight UIDs whose last known location is `region` — the
    /// requests stranded when the instance owning that ring dies.
    /// Cancelled / failed / deadline-expired entries are excluded (they
    /// are already terminal; nothing to recover).
    pub fn uids_at(&self, region: RegionId) -> Vec<Uid> {
        let now = self.clock.now_ns();
        self.inner
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.location == Some(region) && !e.is_terminal(now))
            .map(|(u, _)| *u)
            .collect()
    }

    /// Consume one replay from `uid`'s recovery budget. Marks the entry
    /// failed (counting `requests_failed` once) when the budget is
    /// exhausted; the caller publishes the `Failed` tombstone.
    pub fn begin_replay(&self, uid: Uid) -> ReplayVerdict {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else {
            return ReplayVerdict::Terminal;
        };
        if e.is_terminal(now) {
            return ReplayVerdict::Terminal;
        }
        if e.replays_left == 0 {
            e.failed = true;
            self.failed_ctr.inc();
            self.trace_terminal(uid, e.stage, crate::trace::Verdict::Failed);
            return ReplayVerdict::Exhausted;
        }
        e.replays_left -= 1;
        ReplayVerdict::Replay
    }

    /// Flag `uid` for the recovery sweep to replay from its checkpoint:
    /// the data plane holds a message it can no longer progress (the
    /// instance's role changed mid-queue during a donor steal, or a
    /// downstream ring refused the write). Returns `false` when the
    /// request is untracked or already terminal — the caller then falls
    /// back to a terminal verdict instead.
    pub fn strand(&self, uid: Uid) -> bool {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else { return false };
        if e.is_terminal(now) {
            return false;
        }
        e.stranded = true;
        true
    }

    /// Clear `uid`'s stranded flag — the replay path consumed it (a UID
    /// can be flagged *and* sit on a dead ring; whichever path replays
    /// first must absorb the flag so one sweep never replays twice).
    pub fn unstrand(&self, uid: Uid) {
        if let Some(e) = self.inner.lock().unwrap().get_mut(&uid) {
            e.stranded = false;
        }
    }

    /// Drain the stranded set (recovery sweep: replay each from its
    /// checkpoint, consuming replay budget as usual).
    pub fn take_stranded(&self) -> Vec<Uid> {
        let mut g = self.inner.lock().unwrap();
        g.iter_mut()
            .filter_map(|(u, e)| {
                if e.stranded {
                    e.stranded = false;
                    Some(*u)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Declare `uid` unrecoverable outside the replay path (e.g. no
    /// checkpoint or no surviving stage capacity). Returns `true` when
    /// this call newly failed it. A request that already reached another
    /// terminal state — cancelled, failed, or **deadline expired** — is
    /// left alone: its existing verdict (and the matching tombstone
    /// kind) takes precedence over `Failed`.
    pub fn mark_failed(&self, uid: Uid) -> bool {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else { return false };
        if e.is_terminal(now) {
            return false;
        }
        e.failed = true;
        self.failed_ctr.inc();
        self.trace_terminal(uid, e.stage, crate::trace::Verdict::Failed);
        true
    }

    /// Remaining SLO budget of a tracked request: time until its
    /// deadline, `None` when it has no deadline (or is untracked). The
    /// batch assembler uses this so formation never holds the oldest
    /// member past its deadline; returns `Duration::ZERO` once expired.
    pub fn time_left(&self, uid: Uid) -> Option<Duration> {
        let now = self.clock.now_ns();
        self.inner
            .lock()
            .unwrap()
            .get(&uid)
            .and_then(|e| e.deadline_ns)
            .map(|d| Duration::from_nanos(d.saturating_sub(now)))
    }

    /// Scheduling priority of a tracked request (Standard if unknown —
    /// e.g. the entry aged out of the tracker).
    pub fn priority_of(&self, uid: Uid) -> Priority {
        self.inner
            .lock()
            .unwrap()
            .get(&uid)
            .map(|e| e.priority)
            .unwrap_or(Priority::Standard)
    }

    /// A worker reports that `uid` is executing at `stage`.
    pub fn note_stage(&self, uid: Uid, stage: u32) {
        if let Some(e) = self.inner.lock().unwrap().get_mut(&uid) {
            e.stage = Some(e.stage.map_or(stage, |s| s.max(stage)));
        }
    }

    /// Mark a request cancelled. Returns `true` when this call newly
    /// cancelled it (false if it was already cancelled). Unknown UIDs get
    /// a synthetic cancelled entry so late-arriving messages still drop.
    pub fn cancel(&self, uid: Uid) -> bool {
        let mut g = self.inner.lock().unwrap();
        let mut stage = None;
        let newly = match g.get_mut(&uid) {
            Some(e) => {
                let newly = !e.cancelled;
                e.cancelled = true;
                stage = e.stage;
                newly
            }
            None => {
                g.insert(
                    uid,
                    Entry {
                        priority: Priority::Standard,
                        deadline_ns: None,
                        cancelled: true,
                        failed: false,
                        stranded: false,
                        stage: None,
                        location: None,
                        replays_left: 0,
                        registered_ns: self.clock.now_ns(),
                        deadline_counted: false,
                    },
                );
                true
            }
        };
        if newly {
            self.cancelled_ctr.inc();
            self.trace_terminal(uid, stage, crate::trace::Verdict::Cancelled);
        }
        newly
    }

    /// Data-plane check: should work on `uid` continue? Counts the first
    /// deadline detection into `deadline_missed`.
    pub fn verdict(&self, uid: Uid) -> InFlightVerdict {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else {
            return InFlightVerdict::Proceed;
        };
        if e.cancelled {
            return InFlightVerdict::Cancelled;
        }
        if e.failed {
            return InFlightVerdict::Failed;
        }
        if e.deadline_ns.is_some_and(|d| now > d) {
            if !e.deadline_counted {
                e.deadline_counted = true;
                self.deadline_ctr.inc();
                self.trace_terminal(uid, e.stage, crate::trace::Verdict::DeadlineExceeded);
            }
            return InFlightVerdict::DeadlineExceeded;
        }
        InFlightVerdict::Proceed
    }

    /// Handle-facing probe (same deadline accounting as
    /// [`RequestTracker::verdict`], plus stage progress).
    pub fn probe(&self, uid: Uid) -> TrackedState {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let Some(e) = g.get_mut(&uid) else {
            return TrackedState::Unknown;
        };
        if e.cancelled {
            return TrackedState::Cancelled;
        }
        if e.failed {
            return TrackedState::Failed;
        }
        if e.deadline_ns.is_some_and(|d| now > d) {
            if !e.deadline_counted {
                e.deadline_counted = true;
                self.deadline_ctr.inc();
                self.trace_terminal(uid, e.stage, crate::trace::Verdict::DeadlineExceeded);
            }
            return TrackedState::DeadlineExceeded;
        }
        TrackedState::InFlight { stage: e.stage }
    }

    /// Drop a request's entry (terminal state reached: the result/
    /// tombstone is in the DB, or the handle consumed it).
    pub fn finish(&self, uid: Uid) {
        self.inner.lock().unwrap().remove(&uid);
    }

    /// Tracked request count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when no requests are tracked.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop entries older than `max_age_ns` (lost requests — e.g. §9
    /// message loss — would otherwise leak their entry forever). Run by
    /// the set's housekeeping timer with the DB TTL. Returns how many
    /// entries were purged.
    pub fn purge_older_than(&self, max_age_ns: u64) -> usize {
        let now = self.clock.now_ns();
        let mut g = self.inner.lock().unwrap();
        let before = g.len();
        g.retain(|_, e| now.saturating_sub(e.registered_ns) <= max_age_ns);
        before - g.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{ManualClock, NodeId};

    fn setup() -> (ManualClock, RequestTracker) {
        let c = ManualClock::new();
        c.set(1);
        let t = RequestTracker::new(Arc::new(c.clone()), Registry::new());
        (c, t)
    }

    fn uid(i: u32) -> Uid {
        Uid::fresh(NodeId(i))
    }

    #[test]
    fn register_and_proceed() {
        let (_c, t) = setup();
        let u = uid(1);
        t.register(u, Priority::Interactive, None);
        assert_eq!(t.verdict(u), InFlightVerdict::Proceed);
        assert_eq!(t.priority_of(u), Priority::Interactive);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: None });
        t.note_stage(u, 2);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: Some(2) });
        // Stage progress is monotone (a late entrance report can't rewind).
        t.note_stage(u, 1);
        assert_eq!(t.probe(u), TrackedState::InFlight { stage: Some(2) });
    }

    #[test]
    fn unknown_uid_proceeds() {
        let (_c, t) = setup();
        assert_eq!(t.verdict(uid(9)), InFlightVerdict::Proceed);
        assert_eq!(t.probe(uid(9)), TrackedState::Unknown);
        assert_eq!(t.priority_of(uid(9)), Priority::Standard);
    }

    #[test]
    fn cancel_marks_and_counts_once() {
        let (_c, t) = setup();
        let u = uid(2);
        t.register(u, Priority::Standard, None);
        assert!(t.cancel(u));
        assert!(!t.cancel(u), "second cancel is a no-op");
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
        assert_eq!(t.metrics().counter("requests_cancelled").get(), 1);
    }

    #[test]
    fn cancel_unknown_uid_drops_late_messages() {
        let (_c, t) = setup();
        let u = uid(3);
        assert!(t.cancel(u));
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
    }

    #[test]
    fn time_left_tracks_the_deadline() {
        let (c, t) = setup();
        let u = uid(20);
        t.register(u, Priority::Batch, Some(Duration::from_millis(10)));
        assert_eq!(t.time_left(u), Some(Duration::from_millis(10)));
        c.advance(4_000_000);
        assert_eq!(t.time_left(u), Some(Duration::from_millis(6)));
        c.advance(10_000_000);
        assert_eq!(t.time_left(u), Some(Duration::ZERO), "expired clamps to zero");
        // No deadline / untracked: no budget to report.
        let v = uid(21);
        t.register(v, Priority::Batch, None);
        assert_eq!(t.time_left(v), None);
        assert_eq!(t.time_left(uid(22)), None);
    }

    #[test]
    fn deadline_expires_and_counts_once() {
        let (c, t) = setup();
        let u = uid(4);
        t.register(u, Priority::Batch, Some(Duration::from_millis(10)));
        assert_eq!(t.verdict(u), InFlightVerdict::Proceed);
        c.advance(10_000_001);
        assert_eq!(t.verdict(u), InFlightVerdict::DeadlineExceeded);
        assert_eq!(t.verdict(u), InFlightVerdict::DeadlineExceeded);
        assert_eq!(t.probe(u), TrackedState::DeadlineExceeded);
        assert_eq!(t.metrics().counter("deadline_missed").get(), 1);
    }

    #[test]
    fn cancellation_beats_deadline() {
        let (c, t) = setup();
        let u = uid(5);
        t.register(u, Priority::Standard, Some(Duration::from_millis(1)));
        t.cancel(u);
        c.advance(10_000_000);
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled);
    }

    #[test]
    fn location_tracking_and_uids_at() {
        let (c, t) = setup();
        let (a, b, d) = (uid(10), uid(11), uid(12));
        t.register_full(a, Priority::Standard, None, 1);
        t.register_full(b, Priority::Standard, None, 1);
        t.register_full(d, Priority::Standard, Some(Duration::from_millis(1)), 1);
        t.note_location(a, RegionId(5));
        t.note_location(b, RegionId(5));
        t.note_location(d, RegionId(5));
        t.cancel(b);
        c.advance(2_000_000); // d's deadline lapses
        let mut at = t.uids_at(RegionId(5));
        at.sort();
        assert_eq!(at, vec![a], "cancelled and expired requests are not recoverable");
        assert!(t.uids_at(RegionId(6)).is_empty());
        // Moving on clears the old location.
        t.note_location(a, RegionId(6));
        assert!(t.uids_at(RegionId(5)).is_empty());
        assert_eq!(t.uids_at(RegionId(6)), vec![a]);
    }

    #[test]
    fn replay_budget_exhausts_into_failed() {
        let (_c, t) = setup();
        let u = uid(13);
        t.register_full(u, Priority::Standard, None, 2);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Replay);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Replay);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Exhausted);
        assert_eq!(t.verdict(u), InFlightVerdict::Failed);
        assert_eq!(t.probe(u), TrackedState::Failed);
        assert_eq!(t.metrics().counter("requests_failed").get(), 1);
        // Already failed: further sweeps see a terminal entry.
        assert_eq!(t.begin_replay(u), ReplayVerdict::Terminal);
        assert_eq!(t.metrics().counter("requests_failed").get(), 1, "counted once");
    }

    #[test]
    fn register_with_derives_replay_budget_from_retry_policy() {
        let (_c, t) = setup();
        let u = uid(14);
        // max_attempts = 3 → original dispatch + 2 replays.
        let opts = SubmitOptions::default()
            .with_retry(crate::client::RetryPolicy::attempts(3, Duration::ZERO));
        t.register_with(u, &opts);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Replay);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Replay);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Exhausted);
        // Default policy (1 attempt): no replays at all.
        let v = uid(15);
        t.register_with(v, &SubmitOptions::default());
        assert_eq!(t.begin_replay(v), ReplayVerdict::Exhausted);
    }

    #[test]
    fn strand_flags_in_flight_and_drains_once() {
        let (c, t) = setup();
        let (a, b, d) = (uid(30), uid(31), uid(32));
        t.register_full(a, Priority::Standard, None, 1);
        t.register_full(b, Priority::Standard, None, 1);
        t.register_full(d, Priority::Standard, Some(Duration::from_millis(1)), 1);
        assert!(t.strand(a));
        t.cancel(b);
        assert!(!t.strand(b), "terminal requests are not strandable");
        c.advance(2_000_000);
        assert!(!t.strand(d), "expired deadline wins over stranding");
        assert!(!t.strand(uid(33)), "unknown UIDs are not strandable");
        let drained = t.take_stranded();
        assert_eq!(drained, vec![a]);
        assert!(t.take_stranded().is_empty(), "drained exactly once");
    }

    #[test]
    fn mark_failed_is_terminal_and_counted_once() {
        let (_c, t) = setup();
        let u = uid(16);
        t.register(u, Priority::Standard, None);
        assert!(t.mark_failed(u));
        assert!(!t.mark_failed(u));
        assert_eq!(t.verdict(u), InFlightVerdict::Failed);
        assert!(!t.mark_failed(uid(17)), "unknown UIDs are not failable");
        assert_eq!(t.metrics().counter("requests_failed").get(), 1);
    }

    #[test]
    fn cancel_and_replay_do_not_mix() {
        let (_c, t) = setup();
        let u = uid(18);
        t.register_full(u, Priority::Standard, None, 5);
        t.cancel(u);
        assert_eq!(t.begin_replay(u), ReplayVerdict::Terminal);
        assert_eq!(t.verdict(u), InFlightVerdict::Cancelled, "cancellation wins");
    }

    #[test]
    fn finish_removes_and_purge_sweeps() {
        let (c, t) = setup();
        let a = uid(6);
        let b = uid(7);
        t.register(a, Priority::Standard, None);
        c.advance(1_000_000);
        t.register(b, Priority::Standard, None);
        assert_eq!(t.len(), 2);
        t.finish(a);
        assert_eq!(t.len(), 1);
        c.advance(10_000_000);
        // b is now ~10 ms old; purge anything older than 5 ms.
        assert_eq!(t.purge_older_than(5_000_000), 1);
        assert!(t.is_empty());
    }
}
