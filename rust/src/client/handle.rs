//! [`RequestHandle`]: the typed client-side view of one admitted request,
//! plus the pure [`RequestState`] machine it is built on.
//!
//! A handle is created by a [`crate::client::Gateway`] on admission and
//! owns the request's result path: `status()` folds the database layer
//! (result / tombstone), the [`super::RequestTracker`] (cancellation,
//! deadline, stage progress), and previous observations into one
//! [`RequestStatus`]; `wait()` blocks on the database's condvar waiters
//! instead of busy-polling; `cancel()` flips the control-plane flag the
//! workflow data plane checks before spending compute.

use super::tracker::{RequestTracker, TrackedState};
use super::{Priority, RequestStatus, SubmitOptions};
use crate::db::{DbClient, EntryKind};
use crate::util::Uid;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Granularity at which a blocked `wait()` re-checks cancellation and
/// deadline state. Result arrival wakes the waiter immediately through
/// the DB condvar; this bound only affects how fast a waiter notices a
/// cancel/deadline that happened while it was blocked.
const WAIT_SLICE: Duration = Duration::from_millis(25);

/// Pure request-lifecycle state machine. Terminal states are sticky
/// (first terminal observation wins — e.g. a result arriving after
/// cancellation does not resurrect the request) and stage progress is
/// monotone. Extracted from [`RequestHandle`] so the transition rules are
/// unit-testable without a running cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestState(RequestStatus);

impl RequestState {
    /// A freshly admitted request.
    pub fn new() -> Self {
        Self(RequestStatus::Admitted)
    }

    /// Current status.
    pub fn current(&self) -> RequestStatus {
        self.0
    }

    /// Fold one observation into the state, returning the new status.
    pub fn observe(&mut self, observed: RequestStatus) -> RequestStatus {
        self.0 = match (self.0, observed) {
            (cur, _) if cur.is_terminal() => cur,
            (RequestStatus::Running { stage: a }, RequestStatus::Running { stage: b }) => {
                RequestStatus::Running { stage: a.max(b) }
            }
            // Once running, a bare Admitted observation (e.g. a tracker
            // entry whose stage report lagged) cannot rewind the state.
            (cur @ RequestStatus::Running { .. }, RequestStatus::Admitted) => cur,
            (_, next) => next,
        };
        self.0
    }
}

impl Default for RequestState {
    fn default() -> Self {
        Self::new()
    }
}

/// Outcome of a blocking [`RequestHandle::wait`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WaitOutcome {
    /// The result arrived within the wait budget. The bytes are
    /// delivered exactly once (results can be multi-MB video tensors;
    /// the handle does not retain a copy): a later `wait()` on the same
    /// handle still reports `Done`, with empty bytes.
    Done(Vec<u8>),
    /// The request's deadline passed (result dropped in-pipeline or
    /// never produced in time).
    DeadlineExceeded,
    /// The request was cancelled.
    Cancelled,
    /// The request was lost to a worker-instance failure and its
    /// recovery retries (submit `RetryPolicy`) are exhausted.
    Failed,
    /// The request was rejected (only reachable for handles observed in
    /// the rejected state; gateways report rejection as a
    /// [`crate::client::SubmitError`] instead).
    Rejected,
    /// The wait budget ran out with the request still in flight (e.g.
    /// the message was lost per §9 — no retransmission).
    TimedOut,
}

struct HandleInner {
    machine: RequestState,
    /// Result bytes, parked between the DB fetch (which purges the
    /// replica) and the single `wait()`/`try_result()` call that moves
    /// them out to the caller.
    result: Option<Vec<u8>>,
}

/// Typed handle to one admitted request.
pub struct RequestHandle {
    uid: Uid,
    set: usize,
    priority: Priority,
    tracker: Arc<RequestTracker>,
    db: Arc<DbClient>,
    /// The admitting set's tracer, when tracing is enabled — lets the
    /// caller pull this request's stitched trace after completion.
    tracer: Option<Arc<crate::trace::Tracer>>,
    inner: Mutex<HandleInner>, // lint: lock-rank(handle, 35)
}

impl std::fmt::Debug for RequestHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestHandle")
            .field("uid", &self.uid)
            .field("set", &self.set)
            .field("priority", &self.priority)
            .finish_non_exhaustive()
    }
}

impl RequestHandle {
    /// Build a handle for an admitted request (gateways call this; the
    /// accepting tier supplies its tracker and DB client).
    pub fn new(
        uid: Uid,
        set: usize,
        tracker: Arc<RequestTracker>,
        db: Arc<DbClient>,
        opts: &SubmitOptions,
    ) -> Self {
        Self {
            uid,
            set,
            priority: opts.priority,
            tracker,
            db,
            tracer: None,
            inner: Mutex::new(HandleInner { machine: RequestState::new(), result: None }),
        }
    }

    /// Attach the admitting set's tracer (gateways call this right after
    /// [`RequestHandle::new`] when the deployment traces).
    pub fn attach_tracer(&mut self, tracer: Arc<crate::trace::Tracer>) {
        self.tracer = Some(tracer);
    }

    /// The stitched distributed trace for this request, if tracing is
    /// enabled, the request completed, and its trace was kept (sampled
    /// in, or slow enough for `trace.always_sample_slow_ms`). Drains the
    /// component recorders on demand, so a trace is visible as soon as
    /// its terminal event was recorded.
    pub fn trace(&self) -> Option<crate::trace::Trace> {
        self.tracer.as_ref()?.trace_of(self.uid)
    }

    /// The request UID assigned by the admitting proxy.
    pub fn uid(&self) -> Uid {
        self.uid
    }

    /// Index of the Workflow Set that admitted the request (0 for a
    /// single-set gateway).
    pub fn set(&self) -> usize {
        self.set
    }

    /// The priority the request was submitted with.
    pub fn priority(&self) -> Priority {
        self.priority
    }

    /// Current typed status. Non-blocking; a `Done` observation moves the
    /// result bytes from the DB into the handle.
    pub fn status(&self) -> RequestStatus {
        let mut g = self.inner.lock().unwrap();
        self.refresh(&mut g)
    }

    fn refresh(&self, g: &mut HandleInner) -> RequestStatus {
        if g.machine.current().is_terminal() {
            return g.machine.current();
        }
        // The DB is authoritative for completion: a stored result or
        // tombstone ends the lifecycle.
        if let Some((kind, data)) = self.db.fetch_entry(self.uid) {
            let observed = match kind {
                EntryKind::Result => {
                    g.result = Some(data);
                    RequestStatus::Done
                }
                EntryKind::DeadlineExceeded => RequestStatus::DeadlineExceeded,
                EntryKind::Cancelled => RequestStatus::Cancelled,
                EntryKind::Failed => RequestStatus::Failed,
            };
            self.tracker.finish(self.uid);
            return g.machine.observe(observed);
        }
        match self.tracker.probe(self.uid) {
            TrackedState::Cancelled => g.machine.observe(RequestStatus::Cancelled),
            TrackedState::DeadlineExceeded => {
                g.machine.observe(RequestStatus::DeadlineExceeded)
            }
            TrackedState::Failed => g.machine.observe(RequestStatus::Failed),
            TrackedState::InFlight { stage: Some(s) } => {
                g.machine.observe(RequestStatus::Running { stage: s })
            }
            // Not picked up by a worker yet, or the tracker entry aged
            // out: keep the last known state.
            TrackedState::InFlight { stage: None } | TrackedState::Unknown => {
                g.machine.current()
            }
        }
    }

    /// Cancel the request. Returns `true` if the cancellation took effect
    /// (the request had not already reached a terminal state); in-flight
    /// stage work is dropped by the workflow data plane at its next
    /// tracker check.
    pub fn cancel(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        if self.refresh(&mut g).is_terminal() {
            return false;
        }
        self.tracker.cancel(self.uid);
        g.machine.observe(RequestStatus::Cancelled);
        true
    }

    /// Non-blocking result poll: the bytes, once `Done`. Like
    /// [`RequestHandle::wait`], the bytes are moved out — the first
    /// `Done` observation owns them; `status()` stays `Done` after.
    pub fn try_result(&self) -> Option<Vec<u8>> {
        let mut g = self.inner.lock().unwrap();
        match self.refresh(&mut g) {
            RequestStatus::Done => Some(g.result.take().unwrap_or_default()),
            _ => None,
        }
    }

    /// Block until the request reaches a terminal state or `timeout`
    /// elapses. Blocks on the database layer's condvar waiters (result
    /// arrival wakes immediately) rather than busy-polling.
    pub fn wait(&self, timeout: Duration) -> WaitOutcome {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let mut g = self.inner.lock().unwrap();
                match self.refresh(&mut g) {
                    RequestStatus::Done => {
                        return WaitOutcome::Done(g.result.take().unwrap_or_default())
                    }
                    RequestStatus::DeadlineExceeded => {
                        return WaitOutcome::DeadlineExceeded
                    }
                    RequestStatus::Cancelled => return WaitOutcome::Cancelled,
                    RequestStatus::Failed => return WaitOutcome::Failed,
                    RequestStatus::Rejected { .. } => return WaitOutcome::Rejected,
                    RequestStatus::Admitted | RequestStatus::Running { .. } => {}
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return WaitOutcome::TimedOut;
            }
            self.db.wait_signal((deadline - now).min(WAIT_SLICE));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_machine_happy_path() {
        let mut s = RequestState::new();
        assert_eq!(s.current(), RequestStatus::Admitted);
        assert_eq!(
            s.observe(RequestStatus::Running { stage: 0 }),
            RequestStatus::Running { stage: 0 }
        );
        assert_eq!(
            s.observe(RequestStatus::Running { stage: 2 }),
            RequestStatus::Running { stage: 2 }
        );
        assert_eq!(s.observe(RequestStatus::Done), RequestStatus::Done);
    }

    #[test]
    fn stage_progress_is_monotone() {
        let mut s = RequestState::new();
        s.observe(RequestStatus::Running { stage: 3 });
        assert_eq!(
            s.observe(RequestStatus::Running { stage: 1 }),
            RequestStatus::Running { stage: 3 }
        );
        assert_eq!(
            s.observe(RequestStatus::Admitted),
            RequestStatus::Running { stage: 3 },
            "running never rewinds to admitted"
        );
    }

    #[test]
    fn terminal_states_are_sticky() {
        for terminal in [
            RequestStatus::Done,
            RequestStatus::Cancelled,
            RequestStatus::DeadlineExceeded,
            RequestStatus::Failed,
            RequestStatus::Rejected { retry_after_hint: Duration::from_millis(5) },
        ] {
            let mut s = RequestState::new();
            assert_eq!(s.observe(terminal), terminal);
            assert_eq!(s.observe(RequestStatus::Done), terminal);
            assert_eq!(s.observe(RequestStatus::Running { stage: 9 }), terminal);
            assert_eq!(s.observe(RequestStatus::Cancelled), terminal);
        }
    }

    #[test]
    fn cancellation_racing_completion_first_observation_wins() {
        // Cancel observed first: a late Done cannot resurrect it.
        let mut s = RequestState::new();
        s.observe(RequestStatus::Running { stage: 2 });
        assert_eq!(s.observe(RequestStatus::Cancelled), RequestStatus::Cancelled);
        assert_eq!(s.observe(RequestStatus::Done), RequestStatus::Cancelled);
        // Done observed first: a late cancel is a no-op.
        let mut s = RequestState::new();
        assert_eq!(s.observe(RequestStatus::Done), RequestStatus::Done);
        assert_eq!(s.observe(RequestStatus::Cancelled), RequestStatus::Done);
    }
}
