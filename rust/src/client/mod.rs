//! The unified client gateway API: one typed submission surface over
//! every serving tier.
//!
//! The paper serves "heavy traffic from millions of users" through
//! proxies (§3.2), Workflow Sets (§3.1), and — in this reproduction —
//! a cross-set federation layer. This module makes all of them speak the
//! same language: a [`Gateway`] accepts `(app, payload, SubmitOptions)`
//! and returns a [`RequestHandle`], regardless of whether the tier behind
//! it is one set ([`crate::wset::WorkflowSet`]), the paper's client-side
//! multi-set retry ([`crate::wset::MultiSet`]), or the server-side
//! load-aware router ([`crate::federation::FederationRouter`]).
//!
//! [`SubmitOptions`] carries the request's SLO class:
//! - [`Priority`] — `Interactive` traffic gets reserved admission
//!   headroom at the proxy under overload (§5 extended) and jumps the
//!   RequestScheduler's pull queue (§4.3);
//! - a relative deadline — the workflow data plane drops in-flight stage
//!   work past its deadline and publishes a `DeadlineExceeded` tombstone
//!   to the database layer instead of a result;
//! - a [`RetryPolicy`] applied by the gateway on fast-reject.
//!
//! The lifecycle state lives in the per-set [`RequestTracker`] (control
//! plane) and the memory-centric DB (data plane); [`RequestHandle`]
//! folds both into a typed [`RequestStatus`] with blocking `wait()`
//! (condvar-based, no busy polling) and `cancel()`.

mod handle;
mod tracker;

pub use handle::{RequestHandle, RequestState, WaitOutcome};
pub use tracker::{InFlightVerdict, ReplayVerdict, RequestTracker, TrackedState};

use crate::transport::{AppId, Payload};
use std::time::Duration;

/// Request priority class (SLO tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Priority {
    /// User-facing latency-sensitive traffic: reserved admission
    /// headroom, scheduled ahead of other classes.
    Interactive,
    /// The default class.
    #[default]
    Standard,
    /// Throughput traffic: first to be shed under overload, scheduled
    /// last.
    Batch,
}

impl Priority {
    /// All classes, in scheduling order (highest first).
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Dense index (0 = Interactive) for per-priority tables.
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    /// Lowercase label for metric names and reports.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }
}

/// Gateway-side retry policy, applied in two places: on admission
/// fast-reject (resubmit up to `max_attempts` times with backoff) and
/// after a worker-instance crash (the recovery sweep replays a stranded
/// request's checkpoint up to `max_attempts - 1` times before declaring
/// it [`RequestStatus::Failed`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retry, and no crash-recovery replay).
    pub max_attempts: u32,
    /// Sleep between attempts.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 1, backoff: Duration::from_millis(0) }
    }
}

impl RetryPolicy {
    /// Retry `attempts` times total with a fixed backoff.
    pub fn attempts(max_attempts: u32, backoff: Duration) -> Self {
        Self { max_attempts: max_attempts.max(1), backoff }
    }
}

/// Per-request submission options (the SLO envelope).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// End-to-end deadline, relative to admission. Past it, in-flight
    /// stage work is dropped and the terminal status is
    /// [`RequestStatus::DeadlineExceeded`].
    pub deadline: Option<Duration>,
    /// Applied by the gateway when admission fast-rejects.
    pub retry: RetryPolicy,
}

impl SubmitOptions {
    /// Interactive-class options.
    pub fn interactive() -> Self {
        Self { priority: Priority::Interactive, ..Default::default() }
    }

    /// Batch-class options.
    pub fn batch() -> Self {
        Self { priority: Priority::Batch, ..Default::default() }
    }

    /// Set the priority.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a relative deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Every tried tier is at capacity; the Request Monitor suggests
    /// retrying after `retry_after` (when the oldest admission slides out
    /// of its window).
    Overloaded { retry_after: Duration },
    /// No entrance capacity exists at all (no instances assigned — the
    /// §3.2 fault-isolation "dead set" state).
    NoCapacity,
}

impl SubmitError {
    /// Fold this error's retry hint into a running minimum — gateways
    /// walking several tiers track the soonest time *any* tier frees a
    /// slot.
    pub fn fold_hint(&self, best: Option<Duration>) -> Option<Duration> {
        match self {
            SubmitError::Overloaded { retry_after } => {
                Some(best.map_or(*retry_after, |b| b.min(*retry_after)))
            }
            SubmitError::NoCapacity => best,
        }
    }

    /// The error summarizing a walk whose smallest hint was `best`
    /// (`None` = no tier had capacity at all).
    pub fn from_hint(best: Option<Duration>) -> SubmitError {
        best.map_or(SubmitError::NoCapacity, |retry_after| SubmitError::Overloaded {
            retry_after,
        })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded { retry_after } => {
                write!(f, "overloaded (retry after {:?})", retry_after)
            }
            SubmitError::NoCapacity => write!(f, "no entrance capacity"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Typed request status exposed by [`RequestHandle::status`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestStatus {
    /// Admitted; not yet picked up by a stage worker.
    Admitted,
    /// Executing (or queued) at `stage` — the last stage a worker
    /// reported for this UID.
    Running { stage: u32 },
    /// The result is available (moved into the handle).
    Done,
    /// Fast-rejected; retry after the hint.
    Rejected { retry_after_hint: Duration },
    /// The deadline passed before completion.
    DeadlineExceeded,
    /// Cancelled via [`RequestHandle::cancel`].
    Cancelled,
    /// Lost to a worker-instance failure with recovery retries
    /// exhausted (bounded by the submit [`RetryPolicy`]).
    Failed,
}

impl RequestStatus {
    /// Terminal states never change again.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            RequestStatus::Done
                | RequestStatus::Rejected { .. }
                | RequestStatus::DeadlineExceeded
                | RequestStatus::Cancelled
                | RequestStatus::Failed
        )
    }
}

/// Shared gateway retry scaffold: run one submission `round` up to
/// `opts.retry.max_attempts` times with backoff between rounds, moving
/// the payload from attempt to attempt (a rejecting round hands it
/// back — no clones), and folding the smallest `retry_after` hint into
/// the final error. All three tiers build their [`Gateway`] impl on
/// this so retry semantics cannot drift apart.
///
/// `opts.retry.backoff` is the *first* round's nominal wait; later
/// rounds double it and every wait is seeded-jittered
/// ([`crate::util::backoff_ns`], per-call seed) so many clients
/// rejected by the same overload spike don't resubmit in lockstep and
/// recreate it.
pub(crate) fn retry_rounds(
    opts: &SubmitOptions,
    mut payload: Payload,
    mut round: impl FnMut(Payload) -> Result<RequestHandle, (SubmitError, Payload)>,
) -> Result<RequestHandle, SubmitError> {
    // Distinct seed per retry_rounds call: concurrent callers with the
    // same policy still spread their sleeps apart.
    static BACKOFF_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seed = BACKOFF_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let attempts = opts.retry.max_attempts.max(1);
    let mut best: Option<Duration> = None;
    for attempt in 0..attempts {
        match round(payload) {
            Ok(handle) => return Ok(handle),
            Err((e, p)) => {
                payload = p;
                best = e.fold_hint(best);
            }
        }
        if attempt + 1 < attempts && !opts.retry.backoff.is_zero() {
            let base_ns = opts.retry.backoff.as_nanos().min(u64::MAX as u128) as u64;
            // Cap at 16x the configured backoff so a long retry ladder
            // can't sleep unboundedly past the caller's intent.
            let ns = crate::util::backoff_ns(seed, attempt, base_ns, base_ns.saturating_mul(16));
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }
    Err(SubmitError::from_hint(best))
}

/// The single public serving API, implemented by every tier
/// ([`crate::wset::WorkflowSet`], [`crate::wset::MultiSet`],
/// [`crate::federation::FederationRouter`]).
///
/// `payload` is taken **by value**: the accepting tier moves it onto the
/// wire; tiers that try several sets clone only on fallthrough (the
/// first — usually accepted — attempt never copies).
pub trait Gateway {
    /// Submit with explicit options.
    fn submit_with(
        &self,
        app: AppId,
        payload: Payload,
        opts: SubmitOptions,
    ) -> Result<RequestHandle, SubmitError>;

    /// Submit with default options (Standard priority, no deadline, no
    /// retry).
    fn submit(&self, app: AppId, payload: Payload) -> Result<RequestHandle, SubmitError> {
        self.submit_with(app, payload, SubmitOptions::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_indices_are_dense_and_ordered() {
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Priority::default(), Priority::Standard);
        assert_eq!(Priority::Interactive.label(), "interactive");
    }

    #[test]
    fn options_builders() {
        let o = SubmitOptions::interactive()
            .with_deadline(Duration::from_millis(250))
            .with_retry(RetryPolicy::attempts(3, Duration::from_millis(2)));
        assert_eq!(o.priority, Priority::Interactive);
        assert_eq!(o.deadline, Some(Duration::from_millis(250)));
        assert_eq!(o.retry.max_attempts, 3);
        // Zero attempts clamps to one real try.
        assert_eq!(RetryPolicy::attempts(0, Duration::ZERO).max_attempts, 1);
    }

    #[test]
    fn retry_rounds_folds_min_hint_and_moves_payload() {
        let opts = SubmitOptions::default()
            .with_retry(RetryPolicy::attempts(3, Duration::ZERO));
        let hints = [50u64, 20, 80].map(Duration::from_millis);
        let mut i = 0;
        let err = retry_rounds(&opts, Payload::Bytes(vec![7]), |p| {
            assert_eq!(p, Payload::Bytes(vec![7]), "payload handed back intact");
            let hint = hints[i];
            i += 1;
            Err((SubmitError::Overloaded { retry_after: hint }, p))
        })
        .unwrap_err();
        assert_eq!(i, 3, "all attempts used");
        assert_eq!(
            err,
            SubmitError::Overloaded { retry_after: Duration::from_millis(20) },
            "smallest hint wins"
        );
        // Rounds that never saw capacity fold to NoCapacity.
        let err = retry_rounds(&opts, Payload::Bytes(vec![]), |p| {
            Err((SubmitError::NoCapacity, p))
        })
        .unwrap_err();
        assert_eq!(err, SubmitError::NoCapacity);
    }

    #[test]
    fn terminal_classification() {
        assert!(!RequestStatus::Admitted.is_terminal());
        assert!(!RequestStatus::Running { stage: 1 }.is_terminal());
        assert!(RequestStatus::Done.is_terminal());
        assert!(RequestStatus::Cancelled.is_terminal());
        assert!(RequestStatus::DeadlineExceeded.is_terminal());
        assert!(RequestStatus::Failed.is_terminal());
        assert!(
            RequestStatus::Rejected { retry_after_hint: Duration::ZERO }.is_terminal()
        );
    }
}
