//! RDMA message endpoint: one double-ring buffer per receiving instance
//! (§6: "all senders share the same memory region, enabling the receiver
//! to monitor only a single queue"), workflow messages as frames.
//!
//! The receiver's RS polls [`RdmaEndpoint::recv`] / `recv_timeout`;
//! senders hold a cheap cloneable [`RdmaSender`]. Messages that fail the
//! ring checksum, or pushes abandoned under contention after the retry
//! budget, are *dropped* — §9: OnePiece does not retransmit.

use crate::rdma::{Fabric, RegionId};
use crate::ringbuf::{
    create_ring, PopError, PushError, RingConfig, RingConsumer, RingProducer,
};
use crate::util::{Clock, CodecError, SystemClock};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::WorkflowMessage;

/// Receiving side of an RDMA message queue (owns the ring consumer).
pub struct RdmaEndpoint {
    fabric: Fabric,
    region_id: RegionId,
    config: RingConfig,
    consumer: RingConsumer,
    clock: Arc<dyn Clock>,
    corrupted: u64,
}

/// Sending handle (producer bound to one receiver's ring).
pub struct RdmaSender {
    producer: RingProducer,
    /// Push retries on `Full`/`LostRace` before the message is dropped.
    pub max_retries: usize,
    /// Encode scratch buffer (reused across sends — zero alloc steady
    /// state on the hot path).
    scratch: Vec<u8>,
    dropped: u64,
}

static NEXT_PRODUCER_ID: AtomicU64 = AtomicU64::new(1);

impl RdmaEndpoint {
    /// Create a new endpoint (ring) on `fabric`.
    pub fn new(fabric: &Fabric, config: RingConfig) -> Self {
        let (region_id, region) = create_ring(fabric, config);
        Self {
            fabric: fabric.clone(),
            region_id,
            config,
            consumer: RingConsumer::new(region, config),
            clock: Arc::new(SystemClock),
            corrupted: 0,
        }
    }

    /// Ring region id — senders connect with [`RdmaEndpoint::sender`] or a
    /// raw QP.
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// Create a sender handle for this endpoint usable from any node on
    /// the same fabric (same Workflow Set).
    pub fn sender(&self) -> RdmaSender {
        let qp = self
            .fabric
            .connect(self.region_id)
            .expect("endpoint region vanished");
        let id = NEXT_PRODUCER_ID.fetch_add(1, Ordering::Relaxed);
        RdmaSender {
            producer: RingProducer::new(qp, self.config, self.clock.clone(), id),
            max_retries: 64,
            scratch: Vec::new(),
            dropped: 0,
        }
    }

    /// Build a sender knowing only the fabric and the ring's region id —
    /// the ring geometry is read from the region header (this is how
    /// ResultDeliver connects to downstream instances it learned about
    /// from the NodeManager's routing table).
    pub fn sender_for(fabric: &Fabric, region_id: RegionId) -> RdmaSender {
        let config = crate::ringbuf::ring_config_of(fabric, region_id)
            .expect("region is not a ring buffer");
        let qp = fabric.connect(region_id).expect("region vanished");
        let id = NEXT_PRODUCER_ID.fetch_add(1, Ordering::Relaxed);
        RdmaSender {
            producer: RingProducer::new(qp, config, Arc::new(SystemClock), id),
            max_retries: 64,
            scratch: Vec::new(),
            dropped: 0,
        }
    }

    /// Non-blocking receive. Corrupted frames are counted and skipped
    /// (§6.1 checksum discard); decode failures likewise.
    pub fn recv(&mut self) -> Option<WorkflowMessage> {
        loop {
            match self.consumer.pop()? {
                Ok(bytes) => match WorkflowMessage::decode(&bytes) {
                    Ok(m) => return Some(m),
                    Err(CodecError(_)) => {
                        self.corrupted += 1;
                        continue;
                    }
                },
                Err(PopError::Corrupted { .. }) => {
                    self.corrupted += 1;
                    continue;
                }
            }
        }
    }

    /// Blocking receive with a wall-clock timeout; polls with a short
    /// sleep (the RS's "monitor a designated memory region" loop, §4.3).
    pub fn recv_timeout(&mut self, timeout: std::time::Duration) -> Option<WorkflowMessage> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(m) = self.recv() {
                return Some(m);
            }
            if std::time::Instant::now() >= deadline {
                return None;
            }
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }

    /// Frames dropped due to checksum/decode corruption.
    pub fn corrupted_count(&self) -> u64 {
        self.corrupted
    }

    /// Published-but-unconsumed backlog (approximate).
    pub fn backlog(&self) -> u64 {
        self.consumer.backlog()
    }
}

impl RdmaSender {
    /// Send a message. Returns `false` if dropped (ring persistently full
    /// or lock contention beyond the retry budget) — the no-retransmission
    /// policy of §9 pushes recovery to the application layer.
    pub fn send(&mut self, msg: &WorkflowMessage) -> bool {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        msg.encode_into(&mut scratch);
        let ok = self.send_encoded(&scratch);
        self.scratch = scratch;
        ok
    }

    /// Send pre-encoded frame bytes. Callers that already hold the
    /// encoded message (checkpointing delivery shares one buffer between
    /// the ring push and the DB checkpoint) avoid a second encode.
    pub fn send_encoded(&mut self, bytes: &[u8]) -> bool {
        for _ in 0..=self.max_retries {
            match self.producer.push(bytes, None) {
                Ok(_) => return true,
                Err(PushError::Full) | Err(PushError::LostRace) => {
                    std::thread::yield_now();
                }
                Err(_) => break,
            }
        }
        self.dropped += 1;
        false
    }

    /// Messages dropped by this sender.
    pub fn dropped_count(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: i as u64,
                app: AppId(1),
                stage: StageId(0),
                origin: NodeId(9),
            },
            payload: Payload::Tensor {
                shape: vec![2, 2],
                data: vec![i as f32; 4],
            },
        }
    }

    #[test]
    fn send_recv() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut tx = ep.sender();
        assert!(tx.send(&msg(1)));
        assert!(tx.send(&msg(2)));
        assert_eq!(ep.recv().unwrap(), msg(1));
        assert_eq!(ep.recv().unwrap(), msg(2));
        assert!(ep.recv().is_none());
    }

    #[test]
    fn multiple_senders_fifo_per_sender() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut a = ep.sender();
        let mut b = ep.sender();
        for i in 0..10 {
            if i % 2 == 0 {
                a.send(&msg(i));
            } else {
                b.send(&msg(i));
            }
        }
        let mut got = Vec::new();
        while let Some(m) = ep.recv() {
            got.push(m.header.uid.0 as u32);
        }
        assert_eq!(got.len(), 10);
        // Single-lock ring: global FIFO here (senders are sequential).
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_senders_all_delivered() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 512,
                cap_bytes: 1 << 20,
                ..Default::default()
            },
        );
        let senders: Vec<_> = (0..4).map(|_| ep.sender()).collect();
        let handles: Vec<_> = senders
            .into_iter()
            .enumerate()
            .map(|(t, mut tx)| {
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        assert!(tx.send(&msg(t as u32 * 1000 + i)));
                    }
                })
            })
            .collect();
        let mut got = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got.len() < 400 && std::time::Instant::now() < deadline {
            if let Some(m) = ep.recv_timeout(std::time::Duration::from_millis(100)) {
                got.insert(m.header.uid.0 as u32);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got.len(), 400);
        assert_eq!(ep.corrupted_count(), 0);
    }

    #[test]
    fn full_ring_drops_after_retries() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 2,
                cap_bytes: 256,
                ..Default::default()
            },
        );
        let mut tx = ep.sender();
        tx.max_retries = 2;
        assert!(tx.send(&msg(0)));
        assert!(tx.send(&msg(1)));
        assert!(!tx.send(&msg(2)), "third message must drop: ring full");
        assert_eq!(tx.dropped_count(), 1);
        // Receiver still sees the two delivered messages (§9: loss is
        // tolerated, not retransmitted).
        assert!(ep.recv().is_some());
        assert!(ep.recv().is_some());
        assert!(ep.recv().is_none());
    }
}
