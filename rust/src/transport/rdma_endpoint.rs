//! RDMA message endpoint: one double-ring buffer per receiving instance
//! (§6: "all senders share the same memory region, enabling the receiver
//! to monitor only a single queue"), workflow messages as frames.
//!
//! The receiver's RS polls [`RdmaEndpoint::recv`] / `recv_timeout`;
//! senders hold a cheap cloneable [`RdmaSender`]. Messages that fail the
//! ring checksum, or pushes abandoned under contention after the retry
//! budget, are *dropped* — §9: OnePiece does not retransmit.

use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::rdma::{
    retry_verb, Fabric, PayloadDescriptor, PayloadStager, RdmaError, RegionId,
    PAYLOAD_RELEASE_OFF,
};
use crate::ringbuf::{
    create_ring, Frame, FrameKind, PopError, PushError, RingConfig, RingConsumer, RingProducer,
};
use crate::util::{frame_checksum, Clock, CodecError, SystemClock};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::WorkflowMessage;

/// Ring-path instrumentation handles (set `Registry` metrics), shared by
/// every sender a component owns:
///
/// - `ring_pushes_total` — completed push protocol rounds (one lock
///   acquisition each; a batched push of k frames counts **1**),
/// - `ring_messages_total` — frames published by those rounds,
/// - `ring_verbs_total` — one-sided verbs those rounds spent,
/// - `push_verbs` — histogram of verbs per completed round.
///
/// `ring_verbs_total / ring_messages_total` is the observable
/// verbs-per-message the e15 coalescing drives down; `onepiece federate`
/// prints all of these with the rest of the set counters.
///
/// The payload-plane handles account for the large-payload rendezvous
/// path (DESIGN.md §2):
///
/// - `payload_bytes_copied_total` — post-encode host memcpys of payload
///   bytes. An eager message is charged twice (frame build on send,
///   pop-out on receive); a rendezvous message exactly once (the staging
///   write — the one-sided READ lands at the destination without a host
///   copy, and the 40-byte descriptor frame is control plane, not
///   payload). `copied / messages` near 1× payload size is the zero-copy
///   signature e15 asserts.
/// - `rendezvous_reads_total` — validated one-sided payload pulls,
/// - `payload_regions_live` — staged slabs not yet fully released
///   (gauge; must settle to 0 once consumers release and the stager
///   sweeps — the leak check the fault tests pin down).
#[derive(Clone)]
pub struct RingMetrics {
    pub pushes: Arc<Counter>,
    pub messages: Arc<Counter>,
    pub verbs: Arc<Counter>,
    pub push_verbs: Arc<Histogram>,
    pub payload_bytes_copied: Arc<Counter>,
    pub rendezvous_reads: Arc<Counter>,
    pub payload_regions_live: Arc<Gauge>,
}

impl RingMetrics {
    /// Resolve the ring-path metric handles from a set registry.
    pub fn from_registry(r: &Registry) -> Self {
        Self {
            pushes: r.counter("ring_pushes_total"),
            messages: r.counter("ring_messages_total"),
            verbs: r.counter("ring_verbs_total"),
            push_verbs: r.histogram("push_verbs"),
            payload_bytes_copied: r.counter("payload_bytes_copied_total"),
            rendezvous_reads: r.counter("rendezvous_reads_total"),
            payload_regions_live: r.gauge("payload_regions_live"),
        }
    }

    fn record(&self, accepted: u64, verbs: u64) {
        self.pushes.inc();
        self.messages.add(accepted);
        self.verbs.add(verbs);
        self.push_verbs.record(verbs);
    }
}

/// Receiving side of an RDMA message queue (owns the ring consumer).
pub struct RdmaEndpoint {
    fabric: Fabric,
    region_id: RegionId,
    config: RingConfig,
    consumer: RingConsumer,
    clock: Arc<dyn Clock>,
    corrupted: u64,
    metrics: Option<RingMetrics>,
    /// Tracing hook from the owning instance (None = tracing off): each
    /// validated rendezvous pull records a [`crate::trace::EventKind::RendezvousRead`]
    /// attributed to the resolved message's request.
    trace: Option<crate::trace::TraceHook>,
}

/// Sending handle (producer bound to one receiver's ring).
pub struct RdmaSender {
    producer: RingProducer,
    fabric: Fabric,
    /// Push retries on `Full`/`LostRace` before the message is dropped.
    pub max_retries: usize,
    /// Encode scratch buffer (reused across sends — zero alloc steady
    /// state on the hot path).
    scratch: Vec<u8>,
    metrics: Option<RingMetrics>,
    dropped: u64,
    /// Encoded messages at or above this size go rendezvous (staged slab
    /// + descriptor frame) instead of through the ring inline. 0 = eager
    /// only, the default.
    rendezvous_threshold: usize,
    /// Lazily created slab pool for the rendezvous path.
    stager: Option<PayloadStager>,
    /// Seed for the jittered retry backoff (the producer id — distinct
    /// per sender so contending senders don't back off in lockstep).
    backoff_seed: u64,
}

static NEXT_PRODUCER_ID: AtomicU64 = AtomicU64::new(1);

impl RdmaEndpoint {
    /// Create a new endpoint (ring) on `fabric`.
    pub fn new(fabric: &Fabric, config: RingConfig) -> Self {
        let (region_id, region) = create_ring(fabric, config);
        Self {
            fabric: fabric.clone(),
            region_id,
            config,
            consumer: RingConsumer::new(region, config),
            clock: Arc::new(SystemClock),
            corrupted: 0,
            metrics: None,
            trace: None,
        }
    }

    /// Attach payload-plane instrumentation (eager pop-out copy bytes,
    /// validated rendezvous reads).
    pub fn set_metrics(&mut self, metrics: RingMetrics) {
        self.metrics = Some(metrics);
    }

    /// Attach the owning instance's tracing hook: validated rendezvous
    /// pulls record per-request `RendezvousRead` events.
    pub fn set_trace(&mut self, trace: crate::trace::TraceHook) {
        self.trace = Some(trace);
    }

    /// Ring region id — senders connect with [`RdmaEndpoint::sender`] or a
    /// raw QP.
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// Create a sender handle for this endpoint usable from any node on
    /// the same fabric (same Workflow Set). Fails only if the ring
    /// region was deregistered out from under the endpoint (a dead
    /// instance being reclaimed) — callers drop or re-route rather than
    /// crash the worker.
    pub fn sender(&self) -> Result<RdmaSender, RdmaError> {
        let qp = self.fabric.connect(self.region_id)?;
        let id = NEXT_PRODUCER_ID.fetch_add(1, Ordering::Relaxed);
        Ok(RdmaSender {
            producer: RingProducer::new(qp, self.config, self.clock.clone(), id),
            fabric: self.fabric.clone(),
            max_retries: 64,
            scratch: Vec::new(),
            metrics: None,
            dropped: 0,
            rendezvous_threshold: 0,
            stager: None,
            backoff_seed: id,
        })
    }

    /// Build a sender knowing only the fabric and the ring's region id —
    /// the ring geometry is read from the region header (this is how
    /// ResultDeliver connects to downstream instances it learned about
    /// from the NodeManager's routing table). Fails if the region is
    /// gone or is not a ring buffer (a routing-table entry that outlived
    /// its instance) — callers skip the hop and let NM repair re-route.
    pub fn sender_for(fabric: &Fabric, region_id: RegionId) -> Result<RdmaSender, RdmaError> {
        let config = crate::ringbuf::ring_config_of(fabric, region_id)
            .ok_or(RdmaError::UnknownRegion(region_id))?;
        let qp = fabric.connect(region_id)?;
        let id = NEXT_PRODUCER_ID.fetch_add(1, Ordering::Relaxed);
        Ok(RdmaSender {
            producer: RingProducer::new(qp, config, Arc::new(SystemClock), id),
            fabric: fabric.clone(),
            max_retries: 64,
            scratch: Vec::new(),
            metrics: None,
            dropped: 0,
            rendezvous_threshold: 0,
            stager: None,
            backoff_seed: id,
        })
    }

    /// Non-blocking receive. Corrupted frames are counted and skipped
    /// (§6.1 checksum discard); decode failures likewise. Descriptor
    /// frames are resolved by a one-sided pull from the producer's
    /// staged slab — a pull that fails validation (dead producer, stale
    /// generation, torn payload) is stranded like a corrupt frame,
    /// never delivered.
    pub fn recv(&mut self) -> Option<WorkflowMessage> {
        loop {
            let frame = match self.consumer.pop_frame()? {
                Ok(f) => f,
                Err(PopError::Corrupted { .. }) => {
                    self.corrupted += 1;
                    continue;
                }
            };
            if let Some(m) = self.resolve(frame) {
                return Some(m);
            }
        }
    }

    /// Turn one popped frame into a message: eager bytes decode in
    /// place, descriptors pull the staged payload first. `None` counts
    /// a corruption and means "skip this frame".
    fn resolve(&mut self, frame: Frame) -> Option<WorkflowMessage> {
        let rendezvous = frame.kind == FrameKind::Descriptor;
        let bytes = match frame.kind {
            FrameKind::Eager => {
                if let Some(m) = &self.metrics {
                    // The pop-out copy from ring scratch to the owned
                    // message buffer — eager's second payload copy.
                    m.payload_bytes_copied.add(frame.payload.len() as u64);
                }
                frame.payload
            }
            FrameKind::Descriptor => match self.pull_payload(&frame.payload) {
                Some(b) => b,
                None => {
                    self.corrupted += 1;
                    return None;
                }
            },
        };
        match WorkflowMessage::decode(&bytes) {
            Ok(m) => {
                if rendezvous {
                    if let Some(t) = &self.trace {
                        t.record(
                            m.header.uid,
                            Some(m.header.stage.0),
                            crate::trace::EventKind::RendezvousRead,
                        );
                    }
                }
                Some(m)
            }
            Err(CodecError(_)) => {
                self.corrupted += 1;
                None
            }
        }
    }

    /// Rendezvous pull: **one** vectored one-sided READ covering the
    /// slab header and the payload, then generation + checksum
    /// validation against torn reads racing slab reuse, then one
    /// Fetch&Add on the release counter so the producer can reclaim.
    /// The READ lands at the destination without a host copy; only
    /// validated payloads are released and counted. Under fault
    /// injection, a lost READ/F&A completion is retried a bounded
    /// number of times ([`retry_verb`]) before the descriptor strands —
    /// transient verb loss must not masquerade as a dead producer.
    fn pull_payload(&mut self, desc_bytes: &[u8]) -> Option<Vec<u8>> {
        let desc = PayloadDescriptor::decode(desc_bytes)?;
        let off = desc.offset as usize;
        let len = desc.len as usize;
        if off % 8 != 0 {
            return None;
        }
        // Dead producer: its stager deregistered the slab on Drop, so
        // the connect fails and the descriptor is stranded (recovery
        // replays the message from its checkpoint instead).
        let qp = self.fabric.connect(desc.region).ok()?;
        let hdr_words = off / 8;
        let mut words = vec![0u64; hdr_words + (len + 7) / 8];
        retry_verb(&qp, desc.generation, |qp| qp.post_read_words(0, &mut words)).ok()?;
        if words[0] != desc.generation {
            return None; // slab was re-staged: descriptor is stale
        }
        let mut payload = vec![0u8; len];
        for (i, chunk) in payload.chunks_mut(8).enumerate() {
            let b = words[hdr_words + i].to_le_bytes();
            chunk.copy_from_slice(&b[..chunk.len()]);
        }
        if frame_checksum(&payload) as u64 != desc.checksum {
            return None; // torn read: generation moved mid-pull
        }
        let _ = retry_verb(&qp, desc.generation ^ 1, |qp| {
            qp.post_fetch_add(PAYLOAD_RELEASE_OFF, 1)
        });
        if let Some(m) = &self.metrics {
            m.rendezvous_reads.inc();
        }
        Some(payload)
    }

    /// Batch receive: drain up to `max` messages into `out` in one
    /// round ([`RingConsumer::pop_many`]) — the RS sees a coalesced
    /// arrival burst whole instead of one message per poll, so
    /// downstream batch formation gets its members together. Returns
    /// the number of messages appended; corrupted/undecodable frames are
    /// counted and skipped as in [`RdmaEndpoint::recv`].
    pub fn recv_many(&mut self, max: usize, out: &mut Vec<WorkflowMessage>) -> usize {
        let mut n = 0usize;
        for r in self.consumer.pop_many_frames(max) {
            match r {
                Ok(frame) => {
                    if let Some(m) = self.resolve(frame) {
                        out.push(m);
                        n += 1;
                    }
                }
                Err(PopError::Corrupted { .. }) => self.corrupted += 1,
            }
        }
        n
    }

    /// Blocking receive with a wall-clock timeout; polls with a short
    /// sleep (the RS's "monitor a designated memory region" loop, §4.3).
    pub fn recv_timeout(&mut self, timeout: std::time::Duration) -> Option<WorkflowMessage> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(m) = self.recv() {
                return Some(m);
            }
            if std::time::Instant::now() >= deadline {
                return None;
            }
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }

    /// Frames dropped due to checksum/decode corruption.
    pub fn corrupted_count(&self) -> u64 {
        self.corrupted
    }

    /// Published-but-unconsumed backlog (approximate).
    pub fn backlog(&self) -> u64 {
        self.consumer.backlog()
    }
}

impl RdmaSender {
    /// Attach ring-path instrumentation (set `Registry` handles). Every
    /// completed push round this sender performs is counted.
    pub fn set_metrics(&mut self, metrics: RingMetrics) {
        if let Some(st) = &mut self.stager {
            st.set_gauge(metrics.payload_regions_live.clone());
        }
        self.metrics = Some(metrics);
    }

    /// Set the eager/rendezvous cutover: encoded messages of at least
    /// `bytes` are staged in a registered slab and announced through the
    /// ring by a fixed 40-byte descriptor frame instead of travelling
    /// inline. 0 disables the rendezvous path (the default — matches
    /// `rdma.rendezvous_threshold_bytes`).
    pub fn set_rendezvous_threshold(&mut self, bytes: usize) {
        self.rendezvous_threshold = bytes;
    }

    fn stager_mut(&mut self) -> &mut PayloadStager {
        let fabric = self.fabric.clone();
        let gauge = self.metrics.as_ref().map(|m| m.payload_regions_live.clone());
        self.stager.get_or_insert_with(|| {
            let mut st = PayloadStager::new(fabric);
            if let Some(g) = gauge {
                st.set_gauge(g);
            }
            st
        })
    }

    /// Reclaim staged slabs whose consumers have all released them
    /// (also runs lazily on every stage). Lets `payload_regions_live`
    /// settle to 0 without another send.
    pub fn sweep_staged(&mut self) -> usize {
        self.stager.as_mut().map_or(0, |st| st.sweep())
    }

    /// Staged slabs still awaiting consumer release.
    pub fn staged_live(&self) -> usize {
        self.stager.as_ref().map_or(0, |st| st.live())
    }

    /// Stage one payload for the rendezvous path, charging the staging
    /// copy — the single post-encode memcpy a rendezvous message pays.
    fn stage_for_send(&mut self, payload: &[u8]) -> PayloadDescriptor {
        if let Some(m) = &self.metrics {
            m.payload_bytes_copied.add(payload.len() as u64);
        }
        self.stager_mut().stage(payload, 1)
    }

    /// Bounded exponential backoff between push retries: the first few
    /// retries only yield (transient lock contention clears in that
    /// window), later ones sleep a seeded-jitter exponential — nominally
    /// 1 µs, 2 µs, … capped at **64 µs** with equal jitter
    /// ([`crate::util::backoff_ns`], shared with `client::retry_rounds`
    /// and the verb-retry plane) so contending senders desynchronise
    /// instead of re-colliding on the ring lock in lockstep. The cap is
    /// kept small because workers retry while holding the instance's
    /// shared delivery lock: a long sleep here would head-of-line block
    /// the sibling workers' (and the Interactive fast lane's)
    /// deliveries.
    fn backoff(seed: u64, attempt: usize) {
        if attempt < 8 {
            std::thread::yield_now();
        } else {
            let ns = crate::util::backoff_ns(seed, (attempt - 8).min(6) as u32, 1_000, 64_000);
            std::thread::sleep(std::time::Duration::from_nanos(ns));
        }
    }

    /// Send a message. Returns `false` if dropped (ring persistently full
    /// or lock contention beyond the retry budget) — the no-retransmission
    /// policy of §9 pushes recovery to the application layer.
    pub fn send(&mut self, msg: &WorkflowMessage) -> bool {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        msg.encode_into(&mut scratch);
        let ok = self.send_encoded(&scratch);
        self.scratch = scratch;
        ok
    }

    /// True if a message of `len` encoded bytes can ever be delivered —
    /// `false` means any push would be permanently `Full` and retrying
    /// is futile. A message the rendezvous path would take is always
    /// deliverable: only its fixed 40-byte descriptor enters the ring.
    pub fn accepts(&self, len: usize) -> bool {
        (self.rendezvous_threshold > 0 && len >= self.rendezvous_threshold)
            || self.producer.accepts(len)
    }

    /// Send pre-encoded frame bytes. Callers that already hold the
    /// encoded message (checkpointing delivery shares one buffer between
    /// the ring push and the DB checkpoint) avoid a second encode.
    /// Messages at or above the rendezvous threshold are staged and
    /// announced by descriptor instead of travelling inline.
    pub fn send_encoded(&mut self, bytes: &[u8]) -> bool {
        if self.rendezvous_threshold > 0 && bytes.len() >= self.rendezvous_threshold {
            return self.send_rendezvous(bytes);
        }
        if !self.accepts(bytes.len()) {
            // Permanently oversized: drop now instead of burning the
            // whole retry budget on a Full that can never clear.
            self.dropped += 1;
            return false;
        }
        for attempt in 0..=self.max_retries {
            match self.producer.push(bytes, None) {
                Ok(out) => {
                    if let Some(m) = &self.metrics {
                        m.record(1, out.verbs);
                        // The frame-build copy into the ring — eager's
                        // first payload copy (the pop-out is the second).
                        m.payload_bytes_copied.add(bytes.len() as u64);
                    }
                    return true;
                }
                Err(PushError::Full) | Err(PushError::LostRace) => {
                    Self::backoff(self.backoff_seed, attempt)
                }
                Err(_) => break,
            }
        }
        self.dropped += 1;
        false
    }

    /// Rendezvous send: stage the payload (one copy), push a fixed
    /// 40-byte descriptor frame through the ring. A push that exhausts
    /// its retry budget unstages — the slab is reclaimed immediately
    /// and the descriptor's generation is invalidated so it can never
    /// validate if it leaked.
    fn send_rendezvous(&mut self, payload: &[u8]) -> bool {
        let desc = self.stage_for_send(payload);
        let wire = desc.encode();
        for attempt in 0..=self.max_retries {
            match self.producer.push_frame(&wire, FrameKind::Descriptor, None) {
                Ok(out) => {
                    if let Some(m) = &self.metrics {
                        m.record(1, out.verbs);
                    }
                    return true;
                }
                Err(PushError::Full) | Err(PushError::LostRace) => {
                    Self::backoff(self.backoff_seed, attempt)
                }
                Err(_) => break,
            }
        }
        self.stager_mut().unstage(&desc);
        self.dropped += 1;
        false
    }

    /// Send a batch of pre-encoded frames through [`RingProducer::push_many`]:
    /// the whole batch crosses the fabric under **one** ring lock
    /// acquisition (one push round) when it fits. A partially accepted
    /// batch retries its tail under the same backoff/retry budget as
    /// single sends; the return value is the number of frames delivered
    /// — always a prefix, so per-sender FIFO order is preserved and the
    /// caller routes the undelivered tail through its recovery path.
    pub fn send_batch(&mut self, frames: &[&[u8]]) -> usize {
        let t = self.rendezvous_threshold;
        if t == 0 || !frames.iter().any(|f| f.len() >= t) {
            return self.send_batch_wire(frames, &[]);
        }
        // Mixed batch: stage the oversize members and substitute their
        // 40-byte descriptors; eager and descriptor frames cross the
        // fabric under the same single lock acquisition.
        let mut descs: Vec<Option<PayloadDescriptor>> = Vec::with_capacity(frames.len());
        let mut store: Vec<[u8; crate::rdma::PAYLOAD_DESC_BYTES]> =
            Vec::with_capacity(frames.len());
        let mut kinds: Vec<FrameKind> = Vec::with_capacity(frames.len());
        for f in frames {
            if f.len() >= t {
                let d = self.stage_for_send(f);
                store.push(d.encode());
                descs.push(Some(d));
                kinds.push(FrameKind::Descriptor);
            } else {
                store.push([0u8; crate::rdma::PAYLOAD_DESC_BYTES]);
                descs.push(None);
                kinds.push(FrameKind::Eager);
            }
        }
        let wire: Vec<&[u8]> = frames
            .iter()
            .zip(&descs)
            .zip(&store)
            .map(|((f, d), s)| if d.is_some() { &s[..] } else { *f })
            .collect();
        let sent = self.send_batch_wire(&wire, &kinds);
        // Undelivered tail: reclaim its stagings now — nothing will
        // ever pull or release them.
        for d in descs[sent..].iter().flatten() {
            self.stager_mut().unstage(d);
        }
        sent
    }

    /// The batch push core: `kinds` is empty (all eager) or parallel to
    /// `frames`. Eager frames are charged their frame-build copy as
    /// they are accepted; descriptor frames carry no payload bytes.
    fn send_batch_wire(&mut self, frames: &[&[u8]], kinds: &[FrameKind]) -> usize {
        let mut sent = 0usize;
        let mut attempt = 0usize;
        while sent < frames.len() && attempt <= self.max_retries {
            if !self.accepts(frames[sent].len()) {
                // The next frame can never fit: its Full is permanent,
                // so retrying would head-of-line block the rest of the
                // budget for nothing. Stop here; the undelivered tail
                // is reported to the caller (prefix semantics).
                break;
            }
            let tail_kinds = if kinds.is_empty() { &[][..] } else { &kinds[sent..] };
            match self.producer.push_many_frames(&frames[sent..], tail_kinds, None) {
                Ok(out) => {
                    if let Some(m) = &self.metrics {
                        m.record(out.accepted as u64, out.verbs);
                        for i in sent..sent + out.accepted {
                            if kinds.get(i).copied().unwrap_or_default() == FrameKind::Eager {
                                m.payload_bytes_copied.add(frames[i].len() as u64);
                            }
                        }
                    }
                    sent += out.accepted;
                    if sent < frames.len() {
                        // Ring filled (or a stealer took the tail slots)
                        // mid-batch: back off before re-offering. A
                        // round that made progress resets the budget —
                        // only consecutive fruitless rounds should
                        // exhaust it, or a large batch through a small
                        // ring would drop its tail while the consumer
                        // is draining normally.
                        attempt = 0;
                        Self::backoff(self.backoff_seed, attempt);
                    }
                }
                Err(PushError::Full) | Err(PushError::LostRace) => {
                    Self::backoff(self.backoff_seed, attempt);
                    attempt += 1;
                }
                Err(_) => break,
            }
        }
        self.dropped += (frames.len() - sent) as u64;
        sent
    }

    /// Messages dropped by this sender.
    pub fn dropped_count(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: i as u64,
                app: AppId(1),
                stage: StageId(0),
                origin: NodeId(9),
            },
            payload: Payload::Tensor {
                shape: vec![2, 2],
                data: vec![i as f32; 4],
            },
        }
    }

    #[test]
    fn send_recv() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut tx = ep.sender().unwrap();
        assert!(tx.send(&msg(1)));
        assert!(tx.send(&msg(2)));
        assert_eq!(ep.recv().unwrap(), msg(1));
        assert_eq!(ep.recv().unwrap(), msg(2));
        assert!(ep.recv().is_none());
    }

    #[test]
    fn multiple_senders_fifo_per_sender() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut a = ep.sender().unwrap();
        let mut b = ep.sender().unwrap();
        for i in 0..10 {
            if i % 2 == 0 {
                a.send(&msg(i));
            } else {
                b.send(&msg(i));
            }
        }
        let mut got = Vec::new();
        while let Some(m) = ep.recv() {
            got.push(m.header.uid.0 as u32);
        }
        assert_eq!(got.len(), 10);
        // Single-lock ring: global FIFO here (senders are sequential).
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_senders_all_delivered() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 512,
                cap_bytes: 1 << 20,
                ..Default::default()
            },
        );
        let senders: Vec<_> = (0..4).map(|_| ep.sender().unwrap()).collect();
        let handles: Vec<_> = senders
            .into_iter()
            .enumerate()
            .map(|(t, mut tx)| {
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        assert!(tx.send(&msg(t as u32 * 1000 + i)));
                    }
                })
            })
            .collect();
        let mut got = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got.len() < 400 && std::time::Instant::now() < deadline {
            if let Some(m) = ep.recv_timeout(std::time::Duration::from_millis(100)) {
                got.insert(m.header.uid.0 as u32);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got.len(), 400);
        assert_eq!(ep.corrupted_count(), 0);
    }

    #[test]
    fn send_batch_delivers_in_order_under_one_push_round() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut tx = ep.sender().unwrap();
        let m = RingMetrics::from_registry(&crate::metrics::Registry::new());
        tx.set_metrics(m.clone());
        let msgs: Vec<WorkflowMessage> = (0..5).map(msg).collect();
        let encoded: Vec<Vec<u8>> = msgs.iter().map(|m| m.encode()).collect();
        let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();
        assert_eq!(tx.send_batch(&frames), 5);
        assert_eq!(m.pushes.get(), 1, "whole batch under one lock acquisition");
        assert_eq!(m.messages.get(), 5);
        assert!(m.verbs.get() >= 5, "at least one WL per frame");
        for want in &msgs {
            assert_eq!(&ep.recv().unwrap(), want, "FIFO order preserved");
        }
        assert!(ep.recv().is_none());
    }

    #[test]
    fn send_batch_partial_on_full_ring_returns_prefix() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 2,
                cap_bytes: 512,
                ..Default::default()
            },
        );
        let mut tx = ep.sender().unwrap();
        tx.max_retries = 2;
        let msgs: Vec<WorkflowMessage> = (0..4).map(msg).collect();
        let encoded: Vec<Vec<u8>> = msgs.iter().map(|m| m.encode()).collect();
        let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();
        // Only 2 slots: the accepted prefix is delivered, the tail drops.
        assert_eq!(tx.send_batch(&frames), 2);
        assert_eq!(tx.dropped_count(), 2);
        assert_eq!(ep.recv().unwrap(), msgs[0]);
        assert_eq!(ep.recv().unwrap(), msgs[1]);
        assert!(ep.recv().is_none());
    }

    #[test]
    fn recv_many_drains_a_burst_in_one_round() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        let mut tx = ep.sender().unwrap();
        for i in 0..6 {
            assert!(tx.send(&msg(i)));
        }
        let mut out = Vec::new();
        assert_eq!(ep.recv_many(4, &mut out), 4, "bounded by max");
        assert_eq!(ep.recv_many(64, &mut out), 2);
        assert_eq!(out.len(), 6);
        for (i, m) in out.iter().enumerate() {
            assert_eq!(m.header.uid.0 as u32, i as u32);
        }
        assert_eq!(ep.recv_many(64, &mut out), 0);
    }

    /// A message whose encoded size comfortably exceeds `floats * 4`.
    fn big_msg(i: u32, floats: usize) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: i as u64,
                app: AppId(1),
                stage: StageId(0),
                origin: NodeId(9),
            },
            payload: Payload::Tensor {
                shape: vec![floats as u32],
                data: (0..floats).map(|k| (k as f32).sin()).collect(),
            },
        }
    }

    #[test]
    fn rendezvous_roundtrip_exact_copy_and_read_accounting() {
        let fabric = Fabric::ideal();
        let reg = crate::metrics::Registry::new();
        let m = RingMetrics::from_registry(&reg);
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        ep.set_metrics(m.clone());
        let mut tx = ep.sender().unwrap();
        tx.set_metrics(m.clone());
        tx.set_rendezvous_threshold(1024);

        let big = big_msg(7, 64_000); // ~256 KB encoded
        let enc = big.encode();
        assert!(enc.len() >= 1024);
        assert!(tx.send_encoded(&enc));
        assert_eq!(
            m.payload_bytes_copied.get(),
            enc.len() as u64,
            "rendezvous send pays exactly the one staging copy"
        );
        assert_eq!(m.payload_regions_live.get(), 1);

        assert_eq!(ep.recv().unwrap(), big);
        assert_eq!(m.rendezvous_reads.get(), 1, "one one-sided pull");
        assert_eq!(
            m.payload_bytes_copied.get(),
            enc.len() as u64,
            "the pull lands without a host copy"
        );
        assert_eq!(tx.sweep_staged(), 1, "consumer released the slab");
        assert_eq!(m.payload_regions_live.get(), 0);

        // Below the threshold the eager path is untouched — and charged
        // its two copies (frame build + pop out).
        let small = msg(3);
        let small_len = small.encode().len() as u64;
        assert!(small_len < 1024);
        assert!(tx.send(&small));
        assert_eq!(ep.recv().unwrap(), small);
        assert_eq!(
            m.payload_bytes_copied.get(),
            enc.len() as u64 + 2 * small_len
        );
        assert_eq!(ep.corrupted_count(), 0);
    }

    #[test]
    fn rendezvous_dead_producer_strands_descriptor() {
        let fabric = Fabric::ideal();
        let reg = crate::metrics::Registry::new();
        let m = RingMetrics::from_registry(&reg);
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        ep.set_metrics(m.clone());
        let mut tx = ep.sender().unwrap();
        tx.set_metrics(m.clone());
        tx.set_rendezvous_threshold(1024);
        assert!(tx.send(&big_msg(1, 4096)));
        // Producer dies after the descriptor push, before the pull: its
        // stager deregisters the slab, so the descriptor must strand.
        drop(tx);
        assert_eq!(m.payload_regions_live.get(), 0, "death reclaims slabs");
        assert!(ep.recv().is_none());
        assert_eq!(ep.corrupted_count(), 1, "stranded, not delivered");
        assert_eq!(m.rendezvous_reads.get(), 0);
    }

    #[test]
    fn mixed_batch_eager_and_rendezvous_one_push_round() {
        let fabric = Fabric::ideal();
        let reg = crate::metrics::Registry::new();
        let m = RingMetrics::from_registry(&reg);
        let mut ep = RdmaEndpoint::new(&fabric, RingConfig::default());
        ep.set_metrics(m.clone());
        let mut tx = ep.sender().unwrap();
        tx.set_metrics(m.clone());
        tx.set_rendezvous_threshold(1024);
        let msgs = vec![msg(0), big_msg(1, 8192), msg(2), big_msg(3, 4096)];
        let encoded: Vec<Vec<u8>> = msgs.iter().map(|m| m.encode()).collect();
        let frames: Vec<&[u8]> = encoded.iter().map(Vec::as_slice).collect();
        assert_eq!(tx.send_batch(&frames), 4);
        assert_eq!(m.pushes.get(), 1, "mixed batch under one lock acquisition");
        assert_eq!(m.payload_regions_live.get(), 2);
        let mut out = Vec::new();
        assert_eq!(ep.recv_many(16, &mut out), 4);
        assert_eq!(out, msgs, "FIFO across mixed kinds");
        assert_eq!(m.rendezvous_reads.get(), 2);
        let eager: u64 = (encoded[0].len() + encoded[2].len()) as u64;
        let rdv: u64 = (encoded[1].len() + encoded[3].len()) as u64;
        assert_eq!(m.payload_bytes_copied.get(), 2 * eager + rdv);
        tx.sweep_staged();
        assert_eq!(m.payload_regions_live.get(), 0);
    }

    #[test]
    fn full_ring_drops_after_retries() {
        let fabric = Fabric::ideal();
        let mut ep = RdmaEndpoint::new(
            &fabric,
            RingConfig {
                nslots: 2,
                cap_bytes: 256,
                ..Default::default()
            },
        );
        let mut tx = ep.sender().unwrap();
        tx.max_retries = 2;
        assert!(tx.send(&msg(0)));
        assert!(tx.send(&msg(1)));
        assert!(!tx.send(&msg(2)), "third message must drop: ring full");
        assert_eq!(tx.dropped_count(), 1);
        // Receiver still sees the two delivered messages (§9: loss is
        // tolerated, not retransmitted).
        assert!(ep.recv().is_some());
        assert!(ep.recv().is_some());
        assert!(ep.recv().is_none());
    }
}
