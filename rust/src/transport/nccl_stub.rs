//! NCCL comparison stub — encodes the four limitations (§6 L1–L4) that
//! rule NCCL out as OnePiece's transport, as *enforced restrictions*:
//!
//! - **L1** tensor-only payloads: `send` accepts `&[f32]`, nothing else;
//! - **L2** fixed message sizes: the channel is created with a fixed
//!   element count and rejects anything else;
//! - **L3** GPU interference: every transfer charges busy time to a
//!   simulated device-occupancy meter (collectives run on the device);
//! - **L4** no message context: receivers get bare tensors — no header,
//!   no origin, no app id (the caller must reconstruct context out of
//!   band, which is exactly what OnePiece's message header avoids).
//!
//! The E5 bench uses this to regenerate the §6 comparison table.

/// NCCL-stub error surface: each variant is one paper limitation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NcclError {
    /// L2: payload size differs from the channel's fixed element count.
    WrongSize { expected: usize, got: usize },
}

impl std::fmt::Display for NcclError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NcclError::WrongSize { expected, got } => write!(
                f,
                "NCCL channel is fixed-size: expected {expected} elements, got {got} (limitation L2)"
            ),
        }
    }
}

impl std::error::Error for NcclError {}

/// A fixed-size tensor channel in the style of an NCCL point-to-point.
pub struct NcclStub {
    elems: usize,
    queue: std::collections::VecDeque<Vec<f32>>,
    /// Simulated GPU-busy nanoseconds charged by transfers (L3): NCCL
    /// kernels occupy SMs; modelled at ~1 ns per 8 elements.
    pub gpu_busy_ns: u64,
}

impl NcclStub {
    /// Create a channel carrying exactly `elems` f32 elements per message.
    pub fn new(elems: usize) -> Self {
        Self {
            elems,
            queue: std::collections::VecDeque::new(),
            gpu_busy_ns: 0,
        }
    }

    /// L1+L2: only f32 tensors, only the fixed size.
    pub fn send(&mut self, tensor: &[f32]) -> Result<(), NcclError> {
        if tensor.len() != self.elems {
            return Err(NcclError::WrongSize { expected: self.elems, got: tensor.len() });
        }
        // L3: the transfer occupies the GPU.
        self.gpu_busy_ns += (tensor.len() as u64).div_ceil(8);
        self.queue.push_back(tensor.to_vec());
        Ok(())
    }

    /// L4: receivers get a bare tensor — no header/context.
    pub fn recv(&mut self) -> Option<Vec<f32>> {
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_size_enforced() {
        let mut ch = NcclStub::new(16);
        assert!(ch.send(&vec![0.0; 16]).is_ok());
        assert_eq!(
            ch.send(&vec![0.0; 8]),
            Err(NcclError::WrongSize { expected: 16, got: 8 })
        );
    }

    #[test]
    fn transfers_charge_gpu_time() {
        let mut ch = NcclStub::new(1024);
        ch.send(&vec![0.0; 1024]).unwrap();
        assert!(ch.gpu_busy_ns > 0, "L3: NCCL transfers occupy the GPU");
    }

    #[test]
    fn no_message_context() {
        let mut ch = NcclStub::new(4);
        ch.send(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        let got = ch.recv().unwrap();
        // All we get back is the bare tensor (L4).
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
