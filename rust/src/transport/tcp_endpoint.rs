//! Baseline transport: real TCP loopback sockets through the kernel
//! stack — what the paper replaces with one-sided RDMA ("to address the
//! high data transfer latency associated with traditional TCP-based
//! sockets in large-volume data scenarios", §1). Used by the E5
//! RDMA-vs-TCP bench and as a reference implementation of the same
//! endpoint API.
//!
//! Framing: 4-byte LE length prefix per message. A background acceptor
//! thread drains connections into an mpsc channel.

use super::WorkflowMessage;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::time::Duration;

/// Receiving side: listens on an ephemeral loopback port.
pub struct TcpEndpoint {
    addr: std::net::SocketAddr,
    rx: Receiver<WorkflowMessage>,
    // Keeps the acceptor thread's listener alive implicitly (thread owns
    // it); endpoint drop closes rx which ends delivery but the thread
    // exits only on process end — acceptable for bench/demo use.
}

/// Sending handle: one TCP connection.
pub struct TcpSender {
    stream: TcpStream,
    scratch: Vec<u8>,
}

impl TcpEndpoint {
    /// Bind a loopback listener and start the acceptor thread.
    pub fn new() -> std::io::Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut conn) = conn else { break };
                let tx = tx.clone();
                std::thread::spawn(move || {
                    let mut len_buf = [0u8; 4];
                    loop {
                        if conn.read_exact(&mut len_buf).is_err() {
                            return;
                        }
                        let len = u32::from_le_bytes(len_buf) as usize;
                        let mut buf = vec![0u8; len];
                        if conn.read_exact(&mut buf).is_err() {
                            return;
                        }
                        let Ok(msg) = WorkflowMessage::decode(&buf) else {
                            continue; // corrupted: drop, mirroring §9
                        };
                        if tx.send(msg).is_err() {
                            return;
                        }
                    }
                });
            }
        });
        Ok(Self { addr, rx })
    }

    /// Address senders connect to.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Open a sender connection.
    pub fn sender(&self) -> std::io::Result<TcpSender> {
        let stream = TcpStream::connect(self.addr)?;
        stream.set_nodelay(true)?;
        Ok(TcpSender { stream, scratch: Vec::new() })
    }

    /// Non-blocking receive.
    pub fn recv(&mut self) -> Option<WorkflowMessage> {
        match self.rx.try_recv() {
            Ok(m) => Some(m),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<WorkflowMessage> {
        self.rx.recv_timeout(timeout).ok()
    }
}

impl TcpSender {
    /// Send one length-prefixed message; `false` on socket failure.
    pub fn send(&mut self, msg: &WorkflowMessage) -> bool {
        self.scratch.clear();
        msg.encode_into(&mut self.scratch);
        let len = (self.scratch.len() as u32).to_le_bytes();
        self.stream.write_all(&len).is_ok() && self.stream.write_all(&self.scratch).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{AppId, MessageHeader, Payload, StageId};
    use crate::util::{NodeId, Uid};

    fn msg(i: u32) -> WorkflowMessage {
        WorkflowMessage {
            header: MessageHeader {
                uid: Uid(i as u128),
                ts_ns: 1,
                app: AppId(0),
                stage: StageId(0),
                origin: NodeId(0),
            },
            payload: Payload::Bytes(vec![i as u8; 100]),
        }
    }

    #[test]
    fn tcp_roundtrip() {
        let mut ep = TcpEndpoint::new().unwrap();
        let mut tx = ep.sender().unwrap();
        assert!(tx.send(&msg(1)));
        assert!(tx.send(&msg(2)));
        let a = ep.recv_timeout(Duration::from_secs(5)).unwrap();
        let b = ep.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(a, msg(1));
        assert_eq!(b, msg(2));
    }

    #[test]
    fn multiple_connections() {
        let mut ep = TcpEndpoint::new().unwrap();
        let mut t1 = ep.sender().unwrap();
        let mut t2 = ep.sender().unwrap();
        assert!(t1.send(&msg(10)));
        assert!(t2.send(&msg(20)));
        let mut uids = vec![
            ep.recv_timeout(Duration::from_secs(5)).unwrap().header.uid.0,
            ep.recv_timeout(Duration::from_secs(5)).unwrap().header.uid.0,
        ];
        uids.sort();
        assert_eq!(uids, vec![10, 20]);
    }

    #[test]
    fn empty_recv_is_none() {
        let mut ep = TcpEndpoint::new().unwrap();
        assert!(ep.recv().is_none());
    }
}
