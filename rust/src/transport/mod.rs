//! Inter-service messaging: the workflow message format (§4.1) and three
//! interchangeable transports:
//!
//! - [`RdmaEndpoint`] — the paper's design: one double-ring buffer per
//!   receiver on the simulated RDMA fabric; any number of senders connect
//!   queue pairs and push frames with one-sided verbs.
//! - [`TcpEndpoint`] — the baseline the paper compares against (§1, §6):
//!   real loopback sockets through the kernel stack.
//! - [`NcclStub`] — encodes NCCL's four limitations (L1–L4 in §6) as
//!   type-level restrictions; used by the comparison bench to show *why*
//!   OnePiece cannot be built on NCCL rather than to model its speed.

mod message;
mod nccl_stub;
mod rdma_endpoint;
mod tcp_endpoint;

pub use message::{AppId, MessageHeader, Payload, StageId, WorkflowMessage};
pub use nccl_stub::{NcclError, NcclStub};
pub use rdma_endpoint::{RdmaEndpoint, RdmaSender, RingMetrics};
pub use tcp_endpoint::{TcpEndpoint, TcpSender};
