//! The workflow message (§4.1, Figure 3): a fixed header — UUID assigned
//! by the proxy, proxy receive timestamp, application id, current stage —
//! plus a payload that is either raw bytes or a shaped f32 tensor
//! ("intermediate results can be represented in various data formats,
//! including tensors or raw binary data", §4.4).

use crate::util::{BufReader, BufWriter, CodecError, NodeId, Uid};

/// Application identifier — selects the workflow definition (§4.5) and the
/// user function the TaskWorker invokes (§4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppId(pub u32);

/// Stage index within a workflow (0 = entrance stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StageId(pub u32);

/// Message header (Figure 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MessageHeader {
    /// Request UUID assigned by the proxy (§3.2); tracks the request for
    /// its whole lifecycle and keys the result in the database.
    pub uid: Uid,
    /// Wall-clock ns when the proxy first received the request — used for
    /// end-to-end latency monitoring (§3.2).
    pub ts_ns: u64,
    /// Which application workflow this request belongs to.
    pub app: AppId,
    /// The stage this message is *destined for*.
    pub stage: StageId,
    /// Proxy that admitted the request (for result routing / debugging).
    pub origin: NodeId,
}

/// Message payload: raw bytes or a shaped f32 tensor. Tensors carry their
/// shape so the next stage can bind them to the right executor input
/// without a side channel — the "message context" NCCL lacks (§6 L4).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    Bytes(Vec<u8>),
    /// Row-major f32 tensor.
    Tensor { shape: Vec<u32>, data: Vec<f32> },
    /// Multiple named tensors (e.g. diffusion carries latent + ctx + img).
    Tensors(Vec<(String, Vec<u32>, Vec<f32>)>),
}

impl Payload {
    /// Approximate wire size in bytes.
    pub fn wire_size(&self) -> usize {
        match self {
            Payload::Bytes(b) => b.len() + 8,
            Payload::Tensor { shape, data } => shape.len() * 4 + data.len() * 4 + 16,
            Payload::Tensors(ts) => ts
                .iter()
                .map(|(n, s, d)| n.len() + s.len() * 4 + d.len() * 4 + 24)
                .sum(),
        }
    }

    /// Canonical deterministic encoding: the message wire format minus
    /// the header (tag byte + little-endian fields). Equal payloads
    /// encode to equal bytes, which is what makes this usable both as
    /// the canonicalized input of cache-key derivation and as the
    /// stored form of cached stage outputs (header `uid`/`ts_ns` vary
    /// per request and must never reach a content hash).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(1 + self.wire_size());
        self.encode_into(&mut buf);
        buf
    }

    /// Append the canonical encoding to `buf`.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        let mut w = BufWriter::new(buf);
        write_payload(self, &mut w);
    }

    /// Decode a payload written by [`Payload::encode`].
    pub fn decode(buf: &[u8]) -> Result<Self, CodecError> {
        let mut r = BufReader::new(buf);
        read_payload(&mut r)
    }
}

/// A complete workflow message.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkflowMessage {
    pub header: MessageHeader,
    pub payload: Payload,
}

const TAG_BYTES: u8 = 0;
const TAG_TENSOR: u8 = 1;
const TAG_TENSORS: u8 = 2;

fn write_payload(p: &Payload, w: &mut BufWriter) {
    match p {
        Payload::Bytes(b) => {
            w.put_u8(TAG_BYTES);
            w.put_bytes(b);
        }
        Payload::Tensor { shape, data } => {
            w.put_u8(TAG_TENSOR);
            w.put_u32(shape.len() as u32);
            for &d in shape {
                w.put_u32(d);
            }
            w.put_f32s(data);
        }
        Payload::Tensors(ts) => {
            w.put_u8(TAG_TENSORS);
            w.put_u32(ts.len() as u32);
            for (name, shape, data) in ts {
                w.put_bytes(name.as_bytes());
                w.put_u32(shape.len() as u32);
                for &d in shape {
                    w.put_u32(d);
                }
                w.put_f32s(data);
            }
        }
    }
}

fn read_payload(r: &mut BufReader) -> Result<Payload, CodecError> {
    Ok(match r.get_u8()? {
        TAG_BYTES => Payload::Bytes(r.get_bytes()?.to_vec()),
        TAG_TENSOR => {
            let rank = r.get_u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.get_u32()?);
            }
            Payload::Tensor { shape, data: r.get_f32s()? }
        }
        TAG_TENSORS => {
            let n = r.get_u32()? as usize;
            let mut ts = Vec::with_capacity(n);
            for _ in 0..n {
                let name = String::from_utf8(r.get_bytes()?.to_vec())
                    .map_err(|_| CodecError("bad tensor name"))?;
                let rank = r.get_u32()? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(r.get_u32()?);
                }
                ts.push((name, shape, r.get_f32s()?));
            }
            Payload::Tensors(ts)
        }
        _ => return Err(CodecError("unknown payload tag")),
    })
}

impl WorkflowMessage {
    /// Serialize into `buf` (appending; caller may reuse the allocation).
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        let mut w = BufWriter::new(buf);
        w.put_u128(self.header.uid.0);
        w.put_u64(self.header.ts_ns);
        w.put_u32(self.header.app.0);
        w.put_u32(self.header.stage.0);
        w.put_u32(self.header.origin.0);
        write_payload(&self.payload, &mut w);
    }

    /// Serialize to a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + self.payload.wire_size());
        self.encode_into(&mut buf);
        buf
    }

    /// Decode a message from wire bytes.
    pub fn decode(buf: &[u8]) -> Result<Self, CodecError> {
        let mut r = BufReader::new(buf);
        let header = MessageHeader {
            uid: Uid(r.get_u128()?),
            ts_ns: r.get_u64()?,
            app: AppId(r.get_u32()?),
            stage: StageId(r.get_u32()?),
            origin: NodeId(r.get_u32()?),
        };
        let payload = read_payload(&mut r)?;
        Ok(Self { header, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header() -> MessageHeader {
        MessageHeader {
            uid: Uid(0xABCD_EF01_2345),
            ts_ns: 123_456_789,
            app: AppId(7),
            stage: StageId(2),
            origin: NodeId(3),
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Bytes(b"image bytes here".to_vec()),
        };
        assert_eq!(WorkflowMessage::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn roundtrip_tensor() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Tensor {
                shape: vec![4, 8],
                data: (0..32).map(|i| i as f32 * 0.5).collect(),
            },
        };
        assert_eq!(WorkflowMessage::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn roundtrip_named_tensors() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Tensors(vec![
                ("x".into(), vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                ("ctx".into(), vec![1], vec![9.0]),
            ]),
        };
        assert_eq!(WorkflowMessage::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn decode_rejects_truncation() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Bytes(vec![1, 2, 3]),
        };
        let enc = m.encode();
        for cut in [1, 10, enc.len() - 1] {
            assert!(WorkflowMessage::decode(&enc[..cut]).is_err());
        }
    }

    #[test]
    fn decode_rejects_unknown_tag() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Bytes(vec![]),
        };
        let mut enc = m.encode();
        enc[16 + 8 + 4 + 4 + 4] = 99; // payload tag byte
        assert!(WorkflowMessage::decode(&enc).is_err());
    }

    #[test]
    fn payload_codec_is_canonical_and_header_free() {
        let p = Payload::Tensors(vec![("x".into(), vec![2], vec![1.0, 2.0])]);
        let enc = p.encode();
        assert_eq!(Payload::decode(&enc).unwrap(), p);
        // The payload encoding is exactly the message wire format minus
        // the 36-byte header, and identical payloads under different
        // headers encode identically — the property cache-key
        // derivation and cached-output storage rely on.
        let a = WorkflowMessage { header: header(), payload: p.clone() };
        let mut h2 = header();
        h2.uid = Uid(999);
        h2.ts_ns = 1;
        let b = WorkflowMessage { header: h2, payload: p };
        assert_eq!(&a.encode()[36..], enc.as_slice());
        assert_eq!(&b.encode()[36..], enc.as_slice());
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let m = WorkflowMessage {
            header: header(),
            payload: Payload::Bytes(vec![5; 10]),
        };
        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        let first = buf.clone();
        buf.clear();
        m.encode_into(&mut buf);
        assert_eq!(buf, first);
    }
}
