//! Registered memory regions.
//!
//! Backing store is a slice of `AtomicU64` words: control fields (locks,
//! ring pointers, size slots) are word-aligned and use real atomic
//! CAS/load/store — the exact semantics RDMA atomics give on a NIC. Bulk
//! payload bytes are written through the same words; the ring-buffer
//! protocol guarantees a byte range is owned by exactly one writer at a
//! time (slot exclusivity + checksum for the stolen-lock race), matching
//! the paper's assumption that RDMA writes of a frame are not internally
//! synchronized.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Fabric-wide region identifier (returned by registration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub u64);

/// A registered memory region of fixed byte length (multiple of 8).
#[derive(Clone)]
pub struct MemoryRegion {
    inner: Arc<Inner>,
}

struct Inner {
    words: Box<[AtomicU64]>,
    len_bytes: usize,
}

impl MemoryRegion {
    /// Allocate a zeroed region. `len_bytes` is rounded up to 8 bytes.
    pub fn new(len_bytes: usize) -> Self {
        let words = (len_bytes + 7) / 8;
        let v: Vec<AtomicU64> = (0..words).map(|_| AtomicU64::new(0)).collect();
        Self {
            inner: Arc::new(Inner {
                words: v.into_boxed_slice(),
                len_bytes: words * 8,
            }),
        }
    }

    /// Region length in bytes.
    pub fn len(&self) -> usize {
        self.inner.len_bytes
    }

    /// True if zero-length.
    pub fn is_empty(&self) -> bool {
        self.inner.len_bytes == 0
    }

    #[inline]
    fn word(&self, byte_off: usize) -> &AtomicU64 {
        debug_assert_eq!(byte_off % 8, 0, "unaligned word access at {byte_off}");
        &self.inner.words[byte_off / 8]
    }

    /// Atomic 64-bit load at word-aligned `off`.
    pub fn load_u64(&self, off: usize) -> u64 {
        self.word(off).load(Ordering::SeqCst)
    }

    /// Atomic 64-bit store at word-aligned `off`.
    pub fn store_u64(&self, off: usize, v: u64) {
        self.word(off).store(v, Ordering::SeqCst)
    }

    /// Atomic compare-and-swap; returns the previous value (success iff
    /// it equals `expected`). Mirrors the RDMA `Compare & Swap` verb.
    pub fn cas_u64(&self, off: usize, expected: u64, new: u64) -> Result<u64, u64> {
        self.word(off)
            .compare_exchange(expected, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Atomic fetch-add; mirrors the RDMA `Fetch & Add` verb.
    pub fn fetch_add_u64(&self, off: usize, v: u64) -> u64 {
        self.word(off).fetch_add(v, Ordering::SeqCst)
    }

    /// Bulk write starting at word-aligned `off`. The trailing partial
    /// word is merged read-modify-write (the protocol pads frames to 8
    /// bytes, so cross-writer word sharing cannot occur within a slot).
    ///
    /// Data words use `Relaxed` ordering (plain MOVs — memcpy speed):
    /// publication happens through the size-word CAS (`SeqCst`, a release
    /// operation) in the ring protocol, which makes every prior relaxed
    /// store visible to a consumer that acquires the size word. The
    /// SeqCst-per-word version was 15–20× slower (EXPERIMENTS.md §Perf).
    pub fn write_bytes(&self, off: usize, data: &[u8]) {
        assert!(off % 8 == 0, "write_bytes requires 8-byte alignment");
        assert!(off + data.len() <= self.len(), "write past region end");
        let mut chunks = data.chunks_exact(8);
        let mut w = off / 8;
        for c in chunks.by_ref() {
            self.inner.words[w]
                .store(u64::from_le_bytes(c.try_into().unwrap()), Ordering::Relaxed);
            w += 1;
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let old = self.inner.words[w].load(Ordering::Relaxed);
            let mut bytes = old.to_le_bytes();
            bytes[..rem.len()].copy_from_slice(rem);
            self.inner.words[w].store(u64::from_le_bytes(bytes), Ordering::Relaxed);
        }
    }

    /// Bulk read of `out.len()` bytes starting at word-aligned `off`.
    /// Relaxed per-word loads; see [`MemoryRegion::write_bytes`] for the
    /// publication argument.
    pub fn read_bytes(&self, off: usize, out: &mut [u8]) {
        assert!(off % 8 == 0, "read_bytes requires 8-byte alignment");
        assert!(off + out.len() <= self.len(), "read past region end");
        let mut w = off / 8;
        let mut chunks = out.chunks_exact_mut(8);
        for c in chunks.by_ref() {
            c.copy_from_slice(&self.inner.words[w].load(Ordering::Relaxed).to_le_bytes());
            w += 1;
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.inner.words[w].load(Ordering::Relaxed).to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_up_to_words() {
        assert_eq!(MemoryRegion::new(13).len(), 16);
        assert_eq!(MemoryRegion::new(16).len(), 16);
    }

    #[test]
    fn word_ops() {
        let r = MemoryRegion::new(64);
        r.store_u64(8, 42);
        assert_eq!(r.load_u64(8), 42);
        assert_eq!(r.cas_u64(8, 42, 43), Ok(42));
        assert_eq!(r.cas_u64(8, 42, 44), Err(43));
        assert_eq!(r.fetch_add_u64(8, 2), 43);
        assert_eq!(r.load_u64(8), 45);
    }

    #[test]
    fn byte_roundtrip_aligned() {
        let r = MemoryRegion::new(64);
        let data: Vec<u8> = (0..32).collect();
        r.write_bytes(16, &data);
        let mut out = vec![0u8; 32];
        r.read_bytes(16, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn byte_roundtrip_partial_word() {
        let r = MemoryRegion::new(64);
        let data: Vec<u8> = (0..13).collect(); // trailing partial word
        r.write_bytes(0, &data);
        let mut out = vec![0u8; 13];
        r.read_bytes(0, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn partial_write_preserves_neighbors() {
        let r = MemoryRegion::new(16);
        r.store_u64(8, u64::MAX);
        r.write_bytes(8, &[1, 2, 3]); // only first 3 bytes of word 1
        let mut out = vec![0u8; 8];
        r.read_bytes(8, &mut out);
        assert_eq!(out, [1, 2, 3, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF]);
    }

    #[test]
    #[should_panic(expected = "write past region end")]
    fn write_out_of_bounds_panics() {
        MemoryRegion::new(8).write_bytes(0, &[0u8; 9]);
    }

    #[test]
    fn clone_shares_storage() {
        let a = MemoryRegion::new(8);
        let b = a.clone();
        a.store_u64(0, 9);
        assert_eq!(b.load_u64(0), 9);
    }
}
