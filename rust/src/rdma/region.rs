//! Registered memory regions.
//!
//! Backing store is a slice of `AtomicU64` words: control fields (locks,
//! ring pointers, size slots) are word-aligned and use real atomic
//! CAS/load/store — the exact semantics RDMA atomics give on a NIC. Bulk
//! payload bytes are written through the same words; the ring-buffer
//! protocol guarantees a byte range is owned by exactly one writer at a
//! time (slot exclusivity + checksum for the stolen-lock race), matching
//! the paper's assumption that RDMA writes of a frame are not internally
//! synchronized.

use crate::metrics::Gauge;
use crate::util::frame_checksum;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Fabric-wide region identifier (returned by registration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub u64);

/// A registered memory region of fixed byte length (multiple of 8).
#[derive(Clone)]
pub struct MemoryRegion {
    inner: Arc<Inner>,
}

struct Inner {
    words: Box<[AtomicU64]>,
    len_bytes: usize,
}

impl MemoryRegion {
    /// Allocate a zeroed region. `len_bytes` is rounded up to 8 bytes.
    pub fn new(len_bytes: usize) -> Self {
        let words = (len_bytes + 7) / 8;
        let v: Vec<AtomicU64> = (0..words).map(|_| AtomicU64::new(0)).collect();
        Self {
            inner: Arc::new(Inner {
                words: v.into_boxed_slice(),
                len_bytes: words * 8,
            }),
        }
    }

    /// Region length in bytes.
    pub fn len(&self) -> usize {
        self.inner.len_bytes
    }

    /// True if zero-length.
    pub fn is_empty(&self) -> bool {
        self.inner.len_bytes == 0
    }

    #[inline]
    fn word(&self, byte_off: usize) -> &AtomicU64 {
        debug_assert_eq!(byte_off % 8, 0, "unaligned word access at {byte_off}");
        &self.inner.words[byte_off / 8]
    }

    /// Atomic 64-bit load at word-aligned `off`.
    pub fn load_u64(&self, off: usize) -> u64 {
        self.word(off).load(Ordering::SeqCst)
    }

    /// Atomic 64-bit store at word-aligned `off`.
    pub fn store_u64(&self, off: usize, v: u64) {
        self.word(off).store(v, Ordering::SeqCst)
    }

    /// Atomic compare-and-swap; returns the previous value (success iff
    /// it equals `expected`). Mirrors the RDMA `Compare & Swap` verb.
    pub fn cas_u64(&self, off: usize, expected: u64, new: u64) -> Result<u64, u64> {
        self.word(off)
            .compare_exchange(expected, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    /// Atomic fetch-add; mirrors the RDMA `Fetch & Add` verb.
    pub fn fetch_add_u64(&self, off: usize, v: u64) -> u64 {
        self.word(off).fetch_add(v, Ordering::SeqCst)
    }

    /// Bulk write starting at word-aligned `off`. The trailing partial
    /// word is merged read-modify-write (the protocol pads frames to 8
    /// bytes, so cross-writer word sharing cannot occur within a slot).
    ///
    /// Data words use `Relaxed` ordering (plain MOVs — memcpy speed):
    /// publication happens through the size-word CAS (`SeqCst`, a release
    /// operation) in the ring protocol, which makes every prior relaxed
    /// store visible to a consumer that acquires the size word. The
    /// SeqCst-per-word version was 15–20× slower (EXPERIMENTS.md §Perf).
    pub fn write_bytes(&self, off: usize, data: &[u8]) {
        assert!(off % 8 == 0, "write_bytes requires 8-byte alignment");
        assert!(off + data.len() <= self.len(), "write past region end");
        let mut chunks = data.chunks_exact(8);
        let mut w = off / 8;
        for c in chunks.by_ref() {
            // chunks_exact(8) yields exactly-8-byte slices.
            let mut b = [0u8; 8];
            b.copy_from_slice(c);
            self.inner.words[w].store(u64::from_le_bytes(b), Ordering::Relaxed);
            w += 1;
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let old = self.inner.words[w].load(Ordering::Relaxed);
            let mut bytes = old.to_le_bytes();
            bytes[..rem.len()].copy_from_slice(rem);
            self.inner.words[w].store(u64::from_le_bytes(bytes), Ordering::Relaxed);
        }
    }

    /// Bulk read of `out.len()` bytes starting at word-aligned `off`.
    /// Relaxed per-word loads; see [`MemoryRegion::write_bytes`] for the
    /// publication argument.
    pub fn read_bytes(&self, off: usize, out: &mut [u8]) {
        assert!(off % 8 == 0, "read_bytes requires 8-byte alignment");
        assert!(off + out.len() <= self.len(), "read past region end");
        let mut w = off / 8;
        let mut chunks = out.chunks_exact_mut(8);
        for c in chunks.by_ref() {
            c.copy_from_slice(&self.inner.words[w].load(Ordering::Relaxed).to_le_bytes());
            w += 1;
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.inner.words[w].load(Ordering::Relaxed).to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
}

// --- Rendezvous payload staging (DESIGN.md §2 "Large-payload plane") ---
//
// Above the eager/rendezvous cutover, the payload does not travel through
// the §6.1 ring at all. The sender *stages* it in a registered slab and
// pushes a fixed-size descriptor frame instead; the consumer pulls the
// bytes with one one-sided READ straight out of the producer's memory.
// Slab layout (all offsets in bytes):
//
//   [0..8)   generation — bumped on every (re)stage of the slab, SeqCst
//   [8..16)  release counter — consumers Fetch&Add(+1) after a good read
//   [16..)   payload bytes
//
// A reader racing slab reuse either observes a generation that no longer
// matches its descriptor, or a torn payload whose checksum fails — both
// are detected, never delivered.

/// Byte offset of the generation word in a staged slab.
pub const PAYLOAD_GEN_OFF: usize = 0;
/// Byte offset of the release counter in a staged slab.
pub const PAYLOAD_RELEASE_OFF: usize = 8;
/// Slab header size: the payload starts here.
pub const PAYLOAD_HDR_BYTES: usize = 16;
/// Encoded size of a [`PayloadDescriptor`] — the fixed ring-frame body
/// the rendezvous path pushes in place of the payload.
pub const PAYLOAD_DESC_BYTES: usize = 40;

/// The descriptor frame body: everything a consumer needs to pull and
/// validate one staged payload. Wire format is five little-endian u64s:
/// `[region id][generation][payload byte offset][len][crc32 checksum]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PayloadDescriptor {
    /// Slab region to connect to.
    pub region: RegionId,
    /// Slab generation the payload was staged under.
    pub generation: u64,
    /// Byte offset of the payload inside the slab (= `PAYLOAD_HDR_BYTES`).
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// `frame_checksum` of the payload (CRC32 in the low 32 bits).
    pub checksum: u64,
}

impl PayloadDescriptor {
    /// Encode to the fixed 40-byte wire format.
    pub fn encode(&self) -> [u8; PAYLOAD_DESC_BYTES] {
        let mut out = [0u8; PAYLOAD_DESC_BYTES];
        out[0..8].copy_from_slice(&self.region.0.to_le_bytes());
        out[8..16].copy_from_slice(&self.generation.to_le_bytes());
        out[16..24].copy_from_slice(&self.offset.to_le_bytes());
        out[24..32].copy_from_slice(&self.len.to_le_bytes());
        out[32..40].copy_from_slice(&self.checksum.to_le_bytes());
        out
    }

    /// Decode a 40-byte descriptor; `None` on any other length.
    pub fn decode(bytes: &[u8]) -> Option<Self> {
        if bytes.len() != PAYLOAD_DESC_BYTES {
            return None;
        }
        // Length == PAYLOAD_DESC_BYTES checked above; every 8-byte
        // window is in bounds and exactly sized. lint: allow(l1)
        let w = |i: usize| u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap());
        Some(Self {
            region: RegionId(w(0)),
            generation: w(1),
            offset: w(2),
            len: w(3),
            checksum: w(4),
        })
    }
}

struct Slab {
    id: RegionId,
    region: MemoryRegion,
    /// Payload capacity (bytes after the header).
    cap: usize,
    generation: u64,
    /// Release count that frees the slab for reuse.
    expected: u64,
    in_use: bool,
}

/// Producer-side slab pool for the rendezvous path: stage → (consumers
/// release) → lazy reclaim → reuse. Slabs are registered on the fabric
/// once and reused across payloads (generation bumps invalidate stale
/// descriptors); `Drop` deregisters everything, so a sender's staged
/// memory never outlives it — the leak-free reclaim discipline the
/// recovery sweep relies on.
pub struct PayloadStager {
    fabric: super::fabric::Fabric,
    slabs: Vec<Slab>,
    /// `payload_regions_live` — slabs holding a staged, not yet fully
    /// released payload.
    gauge: Option<Arc<Gauge>>,
}

impl PayloadStager {
    pub fn new(fabric: super::fabric::Fabric) -> Self {
        Self { fabric, slabs: Vec::new(), gauge: None }
    }

    /// Attach the `payload_regions_live` gauge.
    pub fn set_gauge(&mut self, gauge: Arc<Gauge>) {
        self.gauge = Some(gauge);
    }

    /// Stage `payload` for `readers` consumers (each performs one
    /// release Fetch&Add after a successful pull). Exactly one copy of
    /// the payload bytes happens here — the staging write is the
    /// serialization ingress of the rendezvous path.
    pub fn stage(&mut self, payload: &[u8], readers: u64) -> PayloadDescriptor {
        self.sweep();
        let len = payload.len();
        let idx = match self
            .slabs
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.in_use && s.cap >= len)
            .min_by_key(|(_, s)| s.cap)
        {
            Some((i, _)) => i,
            None => {
                // No free slab fits: register a new one. Power-of-two
                // sizing keeps the pool small across mixed payload sizes.
                let cap = len.max(1).next_power_of_two().max(4096);
                let (id, region) = self.fabric.register(PAYLOAD_HDR_BYTES + cap);
                self.slabs.push(Slab {
                    id,
                    region,
                    cap,
                    generation: 0,
                    expected: 0,
                    in_use: false,
                });
                self.slabs.len() - 1
            }
        };
        let slab = &mut self.slabs[idx];
        // Write order matters for the torn-read argument: the generation
        // bump lands (SeqCst) *before* the payload bytes, so a reader
        // holding a stale descriptor sees either a generation mismatch or
        // a mixed-generation payload that fails its checksum.
        slab.generation += 1;
        slab.region.store_u64(PAYLOAD_GEN_OFF, slab.generation);
        slab.region.store_u64(PAYLOAD_RELEASE_OFF, 0);
        slab.region.write_bytes(PAYLOAD_HDR_BYTES, payload);
        slab.expected = readers.max(1);
        slab.in_use = true;
        if let Some(g) = &self.gauge {
            g.add(1);
        }
        PayloadDescriptor {
            region: slab.id,
            generation: slab.generation,
            offset: PAYLOAD_HDR_BYTES as u64,
            len: len as u64,
            checksum: frame_checksum(payload) as u64,
        }
    }

    /// Reclaim every slab whose consumers have all released it. Returns
    /// the number reclaimed. Called lazily by [`PayloadStager::stage`];
    /// callers that want `payload_regions_live` to settle without
    /// another send (tests, shutdown paths) invoke it directly.
    pub fn sweep(&mut self) -> usize {
        let mut freed = 0;
        for s in &mut self.slabs {
            if s.in_use && s.region.load_u64(PAYLOAD_RELEASE_OFF) >= s.expected {
                s.in_use = false;
                freed += 1;
                if let Some(g) = &self.gauge {
                    g.add(-1);
                }
            }
        }
        freed
    }

    /// Abort a staging whose descriptor was never delivered (ring push
    /// exhausted its retries): invalidate the generation and free the
    /// slab immediately. Returns `false` for an unknown / already
    /// reclaimed descriptor.
    pub fn unstage(&mut self, desc: &PayloadDescriptor) -> bool {
        for s in &mut self.slabs {
            if s.id == desc.region && s.generation == desc.generation && s.in_use {
                // Bump so a descriptor that *did* leak can never validate.
                s.generation += 1;
                s.region.store_u64(PAYLOAD_GEN_OFF, s.generation);
                s.in_use = false;
                if let Some(g) = &self.gauge {
                    g.add(-1);
                }
                return true;
            }
        }
        false
    }

    /// Slabs currently holding an unreleased payload.
    pub fn live(&self) -> usize {
        self.slabs.iter().filter(|s| s.in_use).count()
    }

    /// Slab regions registered on the fabric (pool size).
    pub fn registered(&self) -> usize {
        self.slabs.len()
    }
}

impl Drop for PayloadStager {
    fn drop(&mut self) {
        for s in &self.slabs {
            self.fabric.deregister(s.id);
            if s.in_use {
                if let Some(g) = &self.gauge {
                    g.add(-1);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_up_to_words() {
        assert_eq!(MemoryRegion::new(13).len(), 16);
        assert_eq!(MemoryRegion::new(16).len(), 16);
    }

    #[test]
    fn word_ops() {
        let r = MemoryRegion::new(64);
        r.store_u64(8, 42);
        assert_eq!(r.load_u64(8), 42);
        assert_eq!(r.cas_u64(8, 42, 43), Ok(42));
        assert_eq!(r.cas_u64(8, 42, 44), Err(43));
        assert_eq!(r.fetch_add_u64(8, 2), 43);
        assert_eq!(r.load_u64(8), 45);
    }

    #[test]
    fn byte_roundtrip_aligned() {
        let r = MemoryRegion::new(64);
        let data: Vec<u8> = (0..32).collect();
        r.write_bytes(16, &data);
        let mut out = vec![0u8; 32];
        r.read_bytes(16, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn byte_roundtrip_partial_word() {
        let r = MemoryRegion::new(64);
        let data: Vec<u8> = (0..13).collect(); // trailing partial word
        r.write_bytes(0, &data);
        let mut out = vec![0u8; 13];
        r.read_bytes(0, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn partial_write_preserves_neighbors() {
        let r = MemoryRegion::new(16);
        r.store_u64(8, u64::MAX);
        r.write_bytes(8, &[1, 2, 3]); // only first 3 bytes of word 1
        let mut out = vec![0u8; 8];
        r.read_bytes(8, &mut out);
        assert_eq!(out, [1, 2, 3, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF]);
    }

    #[test]
    #[should_panic(expected = "write past region end")]
    fn write_out_of_bounds_panics() {
        MemoryRegion::new(8).write_bytes(0, &[0u8; 9]);
    }

    #[test]
    fn clone_shares_storage() {
        let a = MemoryRegion::new(8);
        let b = a.clone();
        a.store_u64(0, 9);
        assert_eq!(b.load_u64(0), 9);
    }

    #[test]
    fn descriptor_codec_roundtrip() {
        let d = PayloadDescriptor {
            region: RegionId(42),
            generation: 7,
            offset: PAYLOAD_HDR_BYTES as u64,
            len: 1 << 20,
            checksum: 0xDEAD_BEEF,
        };
        let bytes = d.encode();
        assert_eq!(bytes.len(), PAYLOAD_DESC_BYTES);
        assert_eq!(PayloadDescriptor::decode(&bytes), Some(d));
        assert_eq!(PayloadDescriptor::decode(&bytes[..39]), None);
    }

    #[test]
    fn stager_stage_release_reclaim_reuse() {
        let fabric = super::super::fabric::Fabric::ideal();
        let mut st = PayloadStager::new(fabric.clone());
        let payload: Vec<u8> = (0..10_000u32).map(|i| i as u8).collect();
        let d = st.stage(&payload, 2);
        assert_eq!(st.live(), 1);
        assert_eq!(d.len, 10_000);
        assert_eq!(d.checksum, frame_checksum(&payload) as u64);
        // The staged bytes are readable through the fabric.
        let slab = fabric.local(d.region).unwrap();
        assert_eq!(slab.load_u64(PAYLOAD_GEN_OFF), d.generation);
        let mut out = vec![0u8; payload.len()];
        slab.read_bytes(PAYLOAD_HDR_BYTES, &mut out);
        assert_eq!(out, payload);
        // One of two releases: still live. Second: reclaimable.
        slab.fetch_add_u64(PAYLOAD_RELEASE_OFF, 1);
        assert_eq!(st.sweep(), 0);
        slab.fetch_add_u64(PAYLOAD_RELEASE_OFF, 1);
        assert_eq!(st.sweep(), 1);
        assert_eq!(st.live(), 0);
        // Restage reuses the slab with a bumped generation.
        let d2 = st.stage(&payload[..100], 1);
        assert_eq!(d2.region, d.region);
        assert!(d2.generation > d.generation);
        assert_eq!(st.registered(), 1, "the pool reuses slabs");
    }

    #[test]
    fn stager_gauge_and_drop_deregister() {
        let fabric = super::super::fabric::Fabric::ideal();
        let reg = crate::metrics::Registry::new();
        let gauge = reg.gauge("payload_regions_live");
        let rid;
        {
            let mut st = PayloadStager::new(fabric.clone());
            st.set_gauge(gauge.clone());
            let d = st.stage(&[7u8; 64], 1);
            rid = d.region;
            assert_eq!(gauge.get(), 1);
            // Unstage aborts the staging: gauge back to 0, descriptor dead.
            assert!(st.unstage(&d));
            assert!(!st.unstage(&d));
            assert_eq!(gauge.get(), 0);
            assert_ne!(
                fabric.local(rid).unwrap().load_u64(PAYLOAD_GEN_OFF),
                d.generation,
                "an unstaged descriptor must never validate again"
            );
            let _live = st.stage(&[9u8; 64], 3);
            assert_eq!(gauge.get(), 1);
        } // Drop: slabs deregistered, gauge zeroed even for live stagings.
        assert_eq!(gauge.get(), 0);
        assert!(fabric.local(rid).is_err(), "Drop must deregister slabs");
    }
}
