//! The fabric: region registry, queue pairs, latency model, fault
//! injection.
//!
//! Latency is *modelled*: every op returns an [`OpOutcome`] carrying the
//! simulated fabric time. [`WaitMode`] controls whether the caller is also
//! physically delayed (`Spin` for latency-sensitive benches, `None` for
//! functional serving runs where only the returned simulated time is
//! used). This is the substitution boundary: swap this file for real ibv
//! verbs and nothing above changes.

use super::region::{MemoryRegion, RegionId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Latency model for one-sided ops: `base_ns + bytes * ns_per_kib / 1024`.
///
/// Defaults model 100 Gb/s InfiniBand: ~2 µs one-way setup plus
/// 12.5 GB/s line rate (0.08 ns/byte).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Fixed per-op cost (NIC doorbell + propagation), nanoseconds.
    pub base_ns: u64,
    /// Per-byte transfer cost in femtoseconds (1e-6 ns) to keep integer math.
    pub fs_per_byte: u64,
}

impl LatencyModel {
    /// 100 Gb/s InfiniBand-class fabric.
    pub fn infiniband_100g() -> Self {
        Self {
            base_ns: 2_000,
            fs_per_byte: 80_000, // 0.08 ns/byte = 12.5 GB/s
        }
    }

    /// Datacenter TCP-over-Ethernet-class path, for the §6 comparison:
    /// kernel stack + copies dominate (~30 µs base, ~2.5 GB/s effective).
    pub fn tcp_datacenter() -> Self {
        Self {
            base_ns: 30_000,
            fs_per_byte: 400_000, // 0.4 ns/byte = 2.5 GB/s
        }
    }

    /// Simulated duration of transferring `bytes`.
    pub fn duration_ns(&self, bytes: usize) -> u64 {
        self.base_ns + (bytes as u64 * self.fs_per_byte) / 1_000_000
    }
}

/// Whether modelled latency also physically delays the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WaitMode {
    /// Ops complete immediately; simulated time is only reported.
    #[default]
    None,
    /// Spin for the modelled duration (µs-accurate; for latency benches).
    Spin,
}

/// Fabric configuration.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Latency model; `None` = ideal fabric (0 ns).
    pub latency: Option<LatencyModel>,
    pub wait: WaitMode,
    /// Probability a `post_write` is silently dropped (message-loss
    /// injection for the §9 no-retransmission tests). Control-plane ops
    /// (CAS/read) are never dropped — they complete or the QP breaks.
    pub write_drop_prob: f64,
    /// Deterministic seed for the drop process.
    pub seed: u64,
    /// Fault plane (config `faults` block). `None` = no fault state is
    /// ever allocated and every verb takes the exact pre-fault path.
    pub faults: Option<FaultPlan>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self {
            latency: Some(LatencyModel::infiniband_100g()),
            wait: WaitMode::None,
            write_drop_prob: 0.0,
            seed: 0x0EEB_5EED,
            faults: None,
        }
    }
}

/// Deterministic fabric fault plan (DESIGN.md §7): seeded per-verb loss,
/// delayed completions, transient `UnknownRegion` flaps, and directed
/// region partitions with scheduled heal times. Unlike
/// [`FabricConfig::write_drop_prob`] (silent §9 loss the sender never
/// observes), these faults are *visible* to the sender — a lost or
/// partitioned verb returns [`RdmaError::VerbLost`] /
/// [`RdmaError::Partitioned`] so the retry machinery above can act.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Probability any verb's completion is lost ([`RdmaError::VerbLost`];
    /// the op never lands — the sender must retry or strand).
    pub verb_loss_prob: f64,
    /// Probability a verb completes late (lands, but is charged
    /// `delay_ns` extra modelled fabric time).
    pub delay_prob: f64,
    /// Extra modelled ns per delayed completion.
    pub delay_ns: u64,
    /// Probability a verb observes a transient `UnknownRegion` flap —
    /// the region looks deregistered for exactly that op.
    pub flap_prob: f64,
    /// Scheduled directed partition: after this many fabric ops, verbs
    /// targeting victim regions fail with `Partitioned`. Only active
    /// when `partition_ops > 0`.
    pub partition_after_ops: u64,
    /// Partition duration in fabric ops; the link heals (deterministic
    /// heal time) once the op counter passes `after + ops`. 0 = no
    /// scheduled partition.
    pub partition_ops: u64,
    /// Victim selector: regions with `id % partition_group ==
    /// partition_victim` are unreachable while partitioned (a directed
    /// node-pair cut: each instance owns one ring region).
    pub partition_group: u64,
    /// See `partition_group`.
    pub partition_victim: u64,
    /// Deterministic seed for the fault stream (independent of the
    /// write-drop stream).
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            verb_loss_prob: 0.0,
            delay_prob: 0.0,
            delay_ns: 20_000,
            flap_prob: 0.0,
            partition_after_ops: 0,
            partition_ops: 0,
            partition_group: 4,
            partition_victim: 1,
            seed: 0xFA17_5EED,
        }
    }
}

/// Cumulative fault-plane accounting ([`Fabric::fault_stats`]; mirrored
/// into the set registry by the wset housekeeper when faults are on).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Verbs that returned [`RdmaError::VerbLost`].
    pub verbs_lost: u64,
    /// Verbs that completed late (`delay_ns` surcharge).
    pub verbs_delayed: u64,
    /// Transient `UnknownRegion` flaps served.
    pub region_flaps: u64,
    /// Verbs rejected with [`RdmaError::Partitioned`].
    pub partitioned_ops: u64,
    /// Verb-level retries spent by senders ([`Fabric::note_verb_retry`]).
    pub verb_retries: u64,
}

/// Runtime fault state: installed once (`OnceLock`) so the no-faults
/// path never loads any of these atomics.
struct FaultState {
    loss_bits: AtomicU64,
    delay_bits: AtomicU64,
    delay_ns: AtomicU64,
    flap_bits: AtomicU64,
    rng: AtomicU64,
    /// Scheduled partition window in fabric-op indices; `start ==
    /// u64::MAX` means no scheduled window.
    part_start_op: AtomicU64,
    part_end_op: AtomicU64,
    part_group: AtomicU64,
    part_victim: AtomicU64,
    /// Manual partition switch ([`Fabric::start_partition`] /
    /// [`Fabric::heal_partition`]) — test/CLI driven cuts.
    part_manual: std::sync::atomic::AtomicBool,
    /// Gate invocations, including rejected ops. The scheduled partition
    /// window is keyed on this (not `ops_total`, which only counts
    /// *landed* verbs) so a partition that rejects every op still heals.
    gate_ops: AtomicU64,
    lost: AtomicU64,
    delayed: AtomicU64,
    flaps: AtomicU64,
    partitioned: AtomicU64,
    retries: AtomicU64,
}

impl FaultState {
    fn new(plan: &FaultPlan) -> Self {
        let s = Self {
            loss_bits: AtomicU64::new(0),
            delay_bits: AtomicU64::new(0),
            delay_ns: AtomicU64::new(0),
            flap_bits: AtomicU64::new(0),
            rng: AtomicU64::new(plan.seed | 1),
            part_start_op: AtomicU64::new(u64::MAX),
            part_end_op: AtomicU64::new(u64::MAX),
            part_group: AtomicU64::new(plan.partition_group.max(1)),
            part_victim: AtomicU64::new(plan.partition_victim),
            part_manual: std::sync::atomic::AtomicBool::new(false),
            gate_ops: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            delayed: AtomicU64::new(0),
            flaps: AtomicU64::new(0),
            partitioned: AtomicU64::new(0),
            retries: AtomicU64::new(0),
        };
        s.apply(plan);
        s
    }

    fn apply(&self, plan: &FaultPlan) {
        self.loss_bits.store(plan.verb_loss_prob.to_bits(), Ordering::Relaxed);
        self.delay_bits.store(plan.delay_prob.to_bits(), Ordering::Relaxed);
        self.delay_ns.store(plan.delay_ns, Ordering::Relaxed);
        self.flap_bits.store(plan.flap_prob.to_bits(), Ordering::Relaxed);
        self.part_group.store(plan.partition_group.max(1), Ordering::Relaxed);
        self.part_victim.store(plan.partition_victim, Ordering::Relaxed);
        if plan.partition_ops > 0 {
            self.part_start_op.store(plan.partition_after_ops, Ordering::Relaxed);
            self.part_end_op.store(
                plan.partition_after_ops.saturating_add(plan.partition_ops),
                Ordering::Relaxed,
            );
        } else {
            self.part_start_op.store(u64::MAX, Ordering::Relaxed);
            self.part_end_op.store(u64::MAX, Ordering::Relaxed);
        }
    }

    /// xorshift64* roll against an f64-bits probability (same idiom as
    /// the write-drop stream, independent state).
    fn roll(&self, prob_bits: u64) -> bool {
        let prob = f64::from_bits(prob_bits);
        if prob <= 0.0 {
            return false;
        }
        let mut x = self.rng.load(Ordering::Relaxed);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng.store(x, Ordering::Relaxed);
        ((x >> 11) as f64 / (1u64 << 53) as f64) < prob
    }

    fn partition_active(&self, op_idx: u64) -> bool {
        if self.part_manual.load(Ordering::Relaxed) {
            return true;
        }
        let start = self.part_start_op.load(Ordering::Relaxed);
        start != u64::MAX && op_idx >= start && op_idx < self.part_end_op.load(Ordering::Relaxed)
    }
}

/// Error surface of the simulated verbs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RdmaError {
    UnknownRegion(RegionId),
    OutOfBounds { off: usize, len: usize, region_len: usize },
    /// Fault injection: the verb's completion was lost — the op did not
    /// land and the sender must retry (bounded) or strand the work.
    VerbLost,
    /// Fault injection: the link to this region is partitioned; retrying
    /// immediately is pointless — the caller should back off or reroute
    /// until the scheduled heal.
    Partitioned(RegionId),
}

impl std::fmt::Display for RdmaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RdmaError::UnknownRegion(id) => write!(f, "unknown region {id:?}"),
            RdmaError::OutOfBounds { off, len, region_len } => {
                write!(f, "rdma op out of bounds: off={off} len={len} region={region_len}")
            }
            RdmaError::VerbLost => write!(f, "verb completion lost (fault injection)"),
            RdmaError::Partitioned(id) => write!(f, "link to region {id:?} partitioned"),
        }
    }
}

impl std::error::Error for RdmaError {}

/// Result of a completed one-sided op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpOutcome {
    /// Modelled fabric time for this op.
    pub simulated_ns: u64,
    /// False if the op was dropped by fault injection (writes only).
    pub delivered: bool,
}

/// The simulated RDMA network. Cheap to clone; regions are shared.
#[derive(Clone, Default)]
pub struct Fabric {
    inner: Arc<FabricInner>,
}

#[derive(Default)]
struct FabricInner {
    regions: Mutex<HashMap<RegionId, MemoryRegion>>, // lint: lock-rank(fabric_regions, 80)
    next_id: AtomicU64,
    config: Mutex<FabricConfig>, // lint: lock-rank(fabric_config, 81)
    // Hot-path mirror of `config` (EXPERIMENTS.md §Perf: a Mutex lock per
    // verb — 12 verbs per ring push before the e15 coalescing, ~6 after —
    // dominated small-message cost).
    hot_latency_on: std::sync::atomic::AtomicBool,
    hot_base_ns: AtomicU64,
    hot_fs_per_byte: AtomicU64,
    hot_wait_spin: std::sync::atomic::AtomicBool,
    hot_drop_bits: AtomicU64, // f64 bits; 0.0 = no drops
    rng_state: AtomicU64,
    /// Total simulated fabric-time and op/byte counters (for benches).
    sim_ns_total: AtomicU64,
    ops_total: AtomicU64,
    bytes_total: AtomicU64,
    /// Fault plane, installed at most once. Empty (the default) means
    /// the per-verb gate is a single pointer check and nothing else —
    /// the no-`faults` data path is byte-identical to pre-fault builds.
    faults: std::sync::OnceLock<FaultState>,
}

impl Fabric {
    /// New fabric with the given config.
    pub fn new(config: FabricConfig) -> Self {
        let f = Self::default();
        f.inner.rng_state.store(config.seed | 1, Ordering::Relaxed);
        f.apply_hot(&config);
        if let Some(plan) = config.faults {
            f.install_faults(&plan);
        }
        *f.inner.config.lock().unwrap() = config;
        f
    }

    /// Install (or update) the fault plane. Once installed it can be
    /// re-parameterised but never removed — `faults: None` at build time
    /// is the only way to get the zero-overhead path.
    fn install_faults(&self, plan: &FaultPlan) {
        match self.inner.faults.get() {
            Some(state) => state.apply(plan),
            None => {
                // Lost set() race means another thread installed it;
                // re-apply our plan over the winner's state.
                if self.inner.faults.set(FaultState::new(plan)).is_err() {
                    if let Some(state) = self.inner.faults.get() {
                        state.apply(plan);
                    }
                }
            }
        }
    }

    /// Mirror config fields into the lock-free hot path.
    fn apply_hot(&self, config: &FabricConfig) {
        self.inner
            .hot_latency_on
            .store(config.latency.is_some(), Ordering::Relaxed);
        if let Some(m) = config.latency {
            self.inner.hot_base_ns.store(m.base_ns, Ordering::Relaxed);
            self.inner.hot_fs_per_byte.store(m.fs_per_byte, Ordering::Relaxed);
        }
        self.inner
            .hot_wait_spin
            .store(config.wait == WaitMode::Spin, Ordering::Relaxed);
        self.inner
            .hot_drop_bits
            .store(config.write_drop_prob.to_bits(), Ordering::Relaxed);
    }

    /// New ideal fabric (no latency model, no faults).
    pub fn ideal() -> Self {
        Self::new(FabricConfig {
            latency: None,
            ..Default::default()
        })
    }

    /// Register a memory region of `len_bytes`; returns its fabric id.
    pub fn register(&self, len_bytes: usize) -> (RegionId, MemoryRegion) {
        let id = RegionId(self.inner.next_id.fetch_add(1, Ordering::Relaxed));
        let region = MemoryRegion::new(len_bytes);
        self.inner.regions.lock().unwrap().insert(id, region.clone());
        (id, region)
    }

    /// Deregister a region: subsequent [`Fabric::connect`] /
    /// [`Fabric::local`] calls return `UnknownRegion`. Existing queue
    /// pairs keep their (now orphaned) mapping — exactly the window a
    /// real NIC gives between memory deregistration and QP teardown —
    /// which is why the rendezvous path validates generation + checksum
    /// on every pull instead of trusting connectivity. Returns `false`
    /// if the region was never registered (or already deregistered).
    pub fn deregister(&self, id: RegionId) -> bool {
        self.inner.regions.lock().unwrap().remove(&id).is_some()
    }

    /// Open a queue pair to a registered region ("connect").
    pub fn connect(&self, id: RegionId) -> Result<QueuePair, RdmaError> {
        let region = self
            .inner
            .regions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(RdmaError::UnknownRegion(id))?;
        Ok(QueuePair {
            fabric: self.clone(),
            region,
            region_id: id,
        })
    }

    /// Direct (local) handle to a region — the co-located consumer path.
    pub fn local(&self, id: RegionId) -> Result<MemoryRegion, RdmaError> {
        self.inner
            .regions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(RdmaError::UnknownRegion(id))
    }

    /// Total simulated fabric time accumulated across all ops.
    pub fn simulated_ns(&self) -> u64 {
        self.inner.sim_ns_total.load(Ordering::Relaxed)
    }

    /// (ops, bytes) totals.
    pub fn traffic(&self) -> (u64, u64) {
        (
            self.inner.ops_total.load(Ordering::Relaxed),
            self.inner.bytes_total.load(Ordering::Relaxed),
        )
    }

    /// Update the fault/latency config at runtime (tests).
    pub fn set_config(&self, config: FabricConfig) {
        self.apply_hot(&config);
        if let Some(plan) = config.faults {
            self.install_faults(&plan);
        }
        *self.inner.config.lock().unwrap() = config;
    }

    /// Cumulative fault-plane counters; `None` when no fault plan was
    /// ever installed (the off-by-default path registers nothing).
    pub fn fault_stats(&self) -> Option<FaultStats> {
        let s = self.inner.faults.get()?;
        Some(FaultStats {
            verbs_lost: s.lost.load(Ordering::Relaxed),
            verbs_delayed: s.delayed.load(Ordering::Relaxed),
            region_flaps: s.flaps.load(Ordering::Relaxed),
            partitioned_ops: s.partitioned.load(Ordering::Relaxed),
            verb_retries: s.retries.load(Ordering::Relaxed),
        })
    }

    /// Record one sender-side verb retry (the bounded retry loops in the
    /// ring producer / endpoint call this on every re-post after a
    /// [`RdmaError::VerbLost`]). No-op when faults are off, so callers
    /// don't need to gate.
    pub fn note_verb_retry(&self) {
        if let Some(s) = self.inner.faults.get() {
            s.retries.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Manually cut the links to regions with `id % group == victim`
    /// (directed node-pair partition; the chaos tests and `federate
    /// --partition` drive this). Installs a zero-probability fault plan
    /// if none exists so a partition can be driven on an otherwise
    /// fault-free fabric.
    pub fn start_partition(&self, group: u64, victim: u64) {
        if self.inner.faults.get().is_none() {
            self.install_faults(&FaultPlan::default());
        }
        if let Some(s) = self.inner.faults.get() {
            s.part_group.store(group.max(1), Ordering::Relaxed);
            s.part_victim.store(victim, Ordering::Relaxed);
            s.part_manual.store(true, Ordering::Relaxed);
        }
    }

    /// Heal a manual partition (scheduled windows heal on their own).
    pub fn heal_partition(&self) {
        if let Some(s) = self.inner.faults.get() {
            s.part_manual.store(false, Ordering::Relaxed);
        }
    }

    /// Per-verb fault gate. Returns the extra modelled delay in ns (0
    /// almost always), or the injected error. One `OnceLock::get` when
    /// faults are off — nothing else runs.
    fn fault_gate(&self, region_id: RegionId) -> Result<u64, RdmaError> {
        let Some(s) = self.inner.faults.get() else {
            return Ok(0);
        };
        let op_idx = s.gate_ops.fetch_add(1, Ordering::Relaxed);
        if s.partition_active(op_idx) {
            let group = s.part_group.load(Ordering::Relaxed).max(1);
            if region_id.0 % group == s.part_victim.load(Ordering::Relaxed) {
                s.partitioned.fetch_add(1, Ordering::Relaxed);
                return Err(RdmaError::Partitioned(region_id));
            }
        }
        if s.roll(s.flap_bits.load(Ordering::Relaxed)) {
            s.flaps.fetch_add(1, Ordering::Relaxed);
            return Err(RdmaError::UnknownRegion(region_id));
        }
        if s.roll(s.loss_bits.load(Ordering::Relaxed)) {
            s.lost.fetch_add(1, Ordering::Relaxed);
            return Err(RdmaError::VerbLost);
        }
        if s.roll(s.delay_bits.load(Ordering::Relaxed)) {
            s.delayed.fetch_add(1, Ordering::Relaxed);
            let extra = s.delay_ns.load(Ordering::Relaxed);
            self.inner.sim_ns_total.fetch_add(extra, Ordering::Relaxed);
            return Ok(extra);
        }
        Ok(0)
    }

    fn account(&self, bytes: usize) -> u64 {
        let ns = if self.inner.hot_latency_on.load(Ordering::Relaxed) {
            let base = self.inner.hot_base_ns.load(Ordering::Relaxed);
            let fs = self.inner.hot_fs_per_byte.load(Ordering::Relaxed);
            base + (bytes as u64 * fs) / 1_000_000
        } else {
            0
        };
        self.inner.sim_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.inner.ops_total.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        if ns > 0 && self.inner.hot_wait_spin.load(Ordering::Relaxed) {
            let start = std::time::Instant::now();
            while (start.elapsed().as_nanos() as u64) < ns {
                std::hint::spin_loop();
            }
        }
        ns
    }

    /// xorshift64* over shared state — deterministic drop decisions.
    fn roll_drop(&self) -> bool {
        let prob = f64::from_bits(self.inner.hot_drop_bits.load(Ordering::Relaxed));
        if prob <= 0.0 {
            return false;
        }
        let mut x = self.inner.rng_state.load(Ordering::Relaxed);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.inner.rng_state.store(x, Ordering::Relaxed);
        ((x >> 11) as f64 / (1u64 << 53) as f64) < prob
    }
}

/// A connected queue pair: one-sided verbs against one remote region.
/// The remote CPU never executes any code for these ops.
#[derive(Clone)]
pub struct QueuePair {
    fabric: Fabric,
    region: MemoryRegion,
    region_id: RegionId,
}

impl QueuePair {
    /// Remote region id this QP is connected to.
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    /// The fabric this QP is attached to (retry loops use it to account
    /// verb retries via [`Fabric::note_verb_retry`]).
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    fn check(&self, off: usize, len: usize) -> Result<(), RdmaError> {
        if off + len > self.region.len() {
            return Err(RdmaError::OutOfBounds {
                off,
                len,
                region_len: self.region.len(),
            });
        }
        Ok(())
    }

    /// One-sided RDMA WRITE of `data` at remote byte offset `off`.
    pub fn post_write(&self, off: usize, data: &[u8]) -> Result<OpOutcome, RdmaError> {
        self.check(off, data.len())?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(data.len());
        if self.fabric.roll_drop() {
            return Ok(OpOutcome { simulated_ns, delivered: false });
        }
        self.region.write_bytes(off, data);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// One-sided RDMA READ of `out.len()` bytes from remote offset `off`.
    pub fn post_read(&self, off: usize, out: &mut [u8]) -> Result<OpOutcome, RdmaError> {
        self.check(off, out.len())?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(out.len());
        self.region.read_bytes(off, out);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Remote atomic 64-bit read.
    pub fn post_read_u64(&self, off: usize) -> Result<(u64, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(8);
        Ok((self.region.load_u64(off), OpOutcome { simulated_ns, delivered: true }))
    }

    /// Remote atomic 64-bit write.
    pub fn post_write_u64(&self, off: usize, v: u64) -> Result<OpOutcome, RdmaError> {
        self.check(off, 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(8);
        self.region.store_u64(off, v);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// RDMA Compare-and-Swap verb. Returns `Ok(prev)` on success,
    /// `Err(prev)` on mismatch (both after fabric delay).
    pub fn post_cas(
        &self,
        off: usize,
        expected: u64,
        new: u64,
    ) -> Result<(Result<u64, u64>, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(8);
        Ok((
            self.region.cas_u64(off, expected, new),
            OpOutcome { simulated_ns, delivered: true },
        ))
    }

    /// RDMA Fetch-and-Add verb.
    pub fn post_fetch_add(&self, off: usize, v: u64) -> Result<(u64, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(8);
        Ok((
            self.region.fetch_add_u64(off, v),
            OpOutcome { simulated_ns, delivered: true },
        ))
    }

    /// Vectored read of `out.len()` contiguous 64-bit words starting at
    /// word-aligned `off`, charged as **one** verb (`base_ns` + 8·n
    /// bytes). Each word is loaded with the same atomic semantics as
    /// [`QueuePair::post_read_u64`]. This is the GH header-snapshot op:
    /// on real hardware it is a single READ work request covering the
    /// contiguous header words — one doorbell, one completion — instead
    /// of n separate verbs.
    pub fn post_read_words(&self, off: usize, out: &mut [u64]) -> Result<OpOutcome, RdmaError> {
        self.check(off, out.len() * 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(out.len() * 8);
        for (i, w) in out.iter_mut().enumerate() {
            *w = self.region.load_u64(off + i * 8);
        }
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Vectored write of contiguous 64-bit words at word-aligned `off`,
    /// charged as one verb. Control-plane (header) op: like
    /// [`QueuePair::post_write_u64`] it is never dropped by fault
    /// injection — it completes or the QP breaks.
    pub fn post_write_words(&self, off: usize, vals: &[u64]) -> Result<OpOutcome, RdmaError> {
        self.check(off, vals.len() * 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(vals.len() * 8);
        for (i, v) in vals.iter().enumerate() {
            self.region.store_u64(off + i * 8, *v);
        }
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Two CAS work requests posted with a **single doorbell**, charged
    /// as one verb. Both execute in posting order with independent
    /// compare semantics (a doorbell batch on a real QP: the WRs share
    /// the PCIe round trip and completion, not their atomicity). Used by
    /// the ring's UH step to advance both tail words for one `base_ns`.
    #[allow(clippy::too_many_arguments)]
    #[allow(clippy::type_complexity)]
    pub fn post_cas_pair(
        &self,
        off1: usize,
        expected1: u64,
        new1: u64,
        off2: usize,
        expected2: u64,
        new2: u64,
    ) -> Result<((Result<u64, u64>, Result<u64, u64>), OpOutcome), RdmaError> {
        self.check(off1, 8)?;
        self.check(off2, 8)?;
        let extra = self.fabric.fault_gate(self.region_id)?;
        let simulated_ns = extra + self.fabric.account(16);
        let r1 = self.region.cas_u64(off1, expected1, new1);
        let r2 = self.region.cas_u64(off2, expected2, new2);
        Ok(((r1, r2), OpOutcome { simulated_ns, delivered: true }))
    }
}

/// Max re-posts of one verb after [`RdmaError::VerbLost`].
pub const VERB_RETRY_ATTEMPTS: u32 = 4;
/// Wall-clock budget for one verb including its retries.
pub const VERB_RETRY_DEADLINE: std::time::Duration = std::time::Duration::from_millis(5);
const VERB_RETRY_BASE_NS: u64 = 20_000; // first retry waits ~10–20 µs
const VERB_RETRY_CAP_NS: u64 = 320_000;

/// Bounded verb-level retry: runs `op`, re-posting only on
/// [`RdmaError::VerbLost`] — up to [`VERB_RETRY_ATTEMPTS`] attempts
/// within [`VERB_RETRY_DEADLINE`], sleeping a seeded-jitter exponential
/// backoff ([`crate::util::backoff_ns`]) between posts so concurrent
/// senders hit by the same loss burst don't re-post in lockstep.
///
/// Re-posting is safe for **every** verb here, CAS included: the fault
/// gate rejects an op *before* it touches region memory, so a lost verb
/// observably never landed (no at-most-once hazard). `Partitioned`,
/// `UnknownRegion` (flap or real), and bounds errors surface
/// immediately — retrying a cut link burns the deadline for nothing;
/// the caller's strand/recovery machinery owns those. Exhaustion
/// surfaces the final `VerbLost`, which the ring/endpoint callers fold
/// into their existing drop/strand/Case-7 paths.
pub fn retry_verb<T>(
    qp: &QueuePair,
    seed: u64,
    mut op: impl FnMut(&QueuePair) -> Result<T, RdmaError>,
) -> Result<T, RdmaError> {
    let mut attempt = 0u32;
    let start = std::time::Instant::now();
    loop {
        match op(qp) {
            Err(RdmaError::VerbLost)
                if attempt + 1 < VERB_RETRY_ATTEMPTS && start.elapsed() < VERB_RETRY_DEADLINE =>
            {
                qp.fabric().note_verb_retry();
                std::thread::sleep(std::time::Duration::from_nanos(crate::util::backoff_ns(
                    seed,
                    attempt,
                    VERB_RETRY_BASE_NS,
                    VERB_RETRY_CAP_NS,
                )));
                attempt += 1;
            }
            r => return r,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_connect_write_read() {
        let fabric = Fabric::ideal();
        let (id, local) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        qp.post_write(0, b"hello RDMA pad.").unwrap();
        let mut out = vec![0u8; 15];
        qp.post_read(0, &mut out).unwrap();
        assert_eq!(&out, b"hello RDMA pad.");
        // The write is visible to the co-located owner without any CPU
        // involvement on the "remote" side.
        let mut direct = vec![0u8; 5];
        local.read_bytes(0, &mut direct);
        assert_eq!(&direct, b"hello");
    }

    #[test]
    fn deregister_models_producer_death() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(64);
        // A QP opened before death keeps working (NIC teardown window)…
        let qp = fabric.connect(id).unwrap();
        assert!(fabric.deregister(id));
        assert!(qp.post_write(0, &[1u8; 8]).is_ok());
        // …but new connects and locals see the region gone.
        assert!(matches!(fabric.connect(id), Err(RdmaError::UnknownRegion(_))));
        assert!(matches!(fabric.local(id), Err(RdmaError::UnknownRegion(_))));
        assert!(!fabric.deregister(id), "double deregister is a no-op");
    }

    #[test]
    fn unknown_region_rejected() {
        let fabric = Fabric::ideal();
        assert!(matches!(
            fabric.connect(RegionId(99)),
            Err(RdmaError::UnknownRegion(_))
        ));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        assert!(qp.post_write(8, &[1]).is_err());
    }

    #[test]
    fn cas_verb() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        let (r, _) = qp.post_cas(0, 0, 5).unwrap();
        assert_eq!(r, Ok(0));
        let (r, _) = qp.post_cas(0, 0, 6).unwrap();
        assert_eq!(r, Err(5));
    }

    #[test]
    fn latency_model_accounts() {
        let fabric = Fabric::new(FabricConfig {
            latency: Some(LatencyModel::infiniband_100g()),
            ..Default::default()
        });
        let (id, _) = fabric.register(1 << 20);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write(0, &vec![0u8; 1 << 20]).unwrap();
        // 2µs + 1MiB * 0.08 ns/B ≈ 85.9 µs
        assert!(out.simulated_ns > 80_000 && out.simulated_ns < 95_000,
                "ns={}", out.simulated_ns);
        assert_eq!(fabric.simulated_ns(), out.simulated_ns);
    }

    #[test]
    fn tcp_slower_than_rdma_model() {
        let rdma = LatencyModel::infiniband_100g();
        let tcp = LatencyModel::tcp_datacenter();
        for bytes in [0usize, 4096, 1 << 20, 64 << 20] {
            assert!(tcp.duration_ns(bytes) > rdma.duration_ns(bytes));
        }
    }

    #[test]
    fn write_drop_injection() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            write_drop_prob: 1.0,
            ..Default::default()
        });
        let (id, local) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write(0, &[0xAB; 8]).unwrap();
        assert!(!out.delivered);
        assert_eq!(local.load_u64(0), 0, "dropped write must not land");
        // CAS is control-plane: never dropped.
        let (r, _) = qp.post_cas(0, 0, 1).unwrap();
        assert_eq!(r, Ok(0));
    }

    #[test]
    fn vectored_words_roundtrip_as_one_verb() {
        let fabric = Fabric::new(FabricConfig {
            latency: Some(LatencyModel::infiniband_100g()),
            ..Default::default()
        });
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write_words(16, &[7, 8, 9]).unwrap();
        // One verb: one base_ns, not three.
        assert!(out.simulated_ns < 2 * LatencyModel::infiniband_100g().base_ns);
        let mut words = [0u64; 3];
        let out = qp.post_read_words(16, &mut words).unwrap();
        assert_eq!(words, [7, 8, 9]);
        assert!(out.simulated_ns < 2 * LatencyModel::infiniband_100g().base_ns);
        let (ops, bytes) = fabric.traffic();
        assert_eq!(ops, 2, "a vectored op is a single verb");
        assert_eq!(bytes, 48);
        // Bounds still enforced.
        assert!(qp.post_read_words(56, &mut words).is_err());
    }

    #[test]
    fn cas_pair_independent_compares_one_verb() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(32);
        let qp = fabric.connect(id).unwrap();
        qp.post_write_u64(8, 5).unwrap();
        // First CAS matches, second does not: independent outcomes.
        let ((r1, r2), _) = qp.post_cas_pair(0, 0, 1, 8, 0, 2).unwrap();
        assert_eq!(r1, Ok(0));
        assert_eq!(r2, Err(5));
        let (ops, _) = fabric.traffic();
        assert_eq!(ops, 1, "a doorbell-batched CAS pair is one verb");
    }

    #[test]
    fn no_fault_plan_means_no_fault_state() {
        let fabric = Fabric::ideal();
        assert!(fabric.fault_stats().is_none());
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        for _ in 0..100 {
            qp.post_write_u64(0, 7).unwrap();
        }
        // note_verb_retry is a no-op without a plan — still no state.
        fabric.note_verb_retry();
        assert!(fabric.fault_stats().is_none());
    }

    #[test]
    fn verb_loss_injection_is_visible_and_counted() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            faults: Some(FaultPlan {
                verb_loss_prob: 1.0,
                ..Default::default()
            }),
            ..Default::default()
        });
        let (id, local) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        assert!(matches!(qp.post_write_u64(0, 9), Err(RdmaError::VerbLost)));
        assert!(matches!(qp.post_cas(0, 0, 1), Err(RdmaError::VerbLost)));
        assert_eq!(local.load_u64(0), 0, "lost verbs must not land");
        let stats = fabric.fault_stats().unwrap();
        assert_eq!(stats.verbs_lost, 2);
        let (ops, _) = fabric.traffic();
        assert_eq!(ops, 0, "lost verbs are not accounted as landed ops");
        fabric.note_verb_retry();
        assert_eq!(fabric.fault_stats().unwrap().verb_retries, 1);
    }

    #[test]
    fn partial_verb_loss_is_deterministic_for_a_seed() {
        let run = |seed: u64| {
            let fabric = Fabric::new(FabricConfig {
                latency: None,
                faults: Some(FaultPlan {
                    verb_loss_prob: 0.3,
                    seed,
                    ..Default::default()
                }),
                ..Default::default()
            });
            let (id, _) = fabric.register(64);
            let qp = fabric.connect(id).unwrap();
            (0..256)
                .map(|_| qp.post_write_u64(0, 1).is_ok())
                .collect::<Vec<_>>()
        };
        let a = run(1234);
        assert_eq!(a, run(1234), "same seed, same loss pattern");
        assert!(a.iter().any(|ok| *ok) && a.iter().any(|ok| !*ok));
    }

    #[test]
    fn delayed_completion_lands_with_surcharge() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            faults: Some(FaultPlan {
                delay_prob: 1.0,
                delay_ns: 50_000,
                ..Default::default()
            }),
            ..Default::default()
        });
        let (id, local) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write_u64(0, 42).unwrap();
        assert_eq!(out.simulated_ns, 50_000, "ideal latency + delay surcharge");
        assert_eq!(local.load_u64(0), 42, "delayed verbs still land");
        assert_eq!(fabric.fault_stats().unwrap().verbs_delayed, 1);
    }

    #[test]
    fn region_flap_is_transient_unknown_region() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            faults: Some(FaultPlan {
                flap_prob: 1.0,
                ..Default::default()
            }),
            ..Default::default()
        });
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        assert!(matches!(qp.post_read_u64(0), Err(RdmaError::UnknownRegion(_))));
        assert!(fabric.fault_stats().unwrap().region_flaps >= 1);
        // The region is still registered — the flap is the link lying,
        // not a deregistration.
        assert!(fabric.connect(id).is_ok());
    }

    #[test]
    fn scheduled_partition_cuts_victims_then_heals() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            faults: Some(FaultPlan {
                partition_after_ops: 2,
                partition_ops: 3,
                partition_group: 1, // every region is a victim
                partition_victim: 0,
                ..Default::default()
            }),
            ..Default::default()
        });
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        let mut results = Vec::new();
        for _ in 0..8 {
            results.push(qp.post_write_u64(0, 1).is_ok());
        }
        // Ops 0,1 land; 2,3,4 partitioned; 5+ healed (deterministic).
        assert_eq!(results, [true, true, false, false, false, true, true, true]);
        assert_eq!(fabric.fault_stats().unwrap().partitioned_ops, 3);
    }

    #[test]
    fn manual_partition_targets_victim_group_and_heals() {
        let fabric = Fabric::ideal();
        let (id0, _) = fabric.register(64); // RegionId(0)
        let (id1, _) = fabric.register(64); // RegionId(1)
        let qp0 = fabric.connect(id0).unwrap();
        let qp1 = fabric.connect(id1).unwrap();
        // Cut only odd regions.
        fabric.start_partition(2, 1);
        assert!(qp0.post_write_u64(0, 1).is_ok(), "non-victim unaffected");
        assert!(matches!(
            qp1.post_write_u64(0, 1),
            Err(RdmaError::Partitioned(r)) if r == id1
        ));
        fabric.heal_partition();
        assert!(qp1.post_write_u64(0, 1).is_ok(), "healed link carries verbs");
        let stats = fabric.fault_stats().unwrap();
        assert_eq!(stats.partitioned_ops, 1);
        assert_eq!(stats.verbs_lost, 0, "manual partition injects no loss");
    }

    #[test]
    fn retry_verb_resolves_partial_loss_and_bounds_total_loss() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            faults: Some(FaultPlan {
                verb_loss_prob: 0.5,
                seed: 99,
                ..Default::default()
            }),
            ..Default::default()
        });
        let (id, local) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        // With 4 attempts per op at 50% loss, 64 writes virtually all
        // land; each landed write is observable.
        let mut landed = 0u64;
        for i in 0..64u64 {
            if retry_verb(&qp, i, |qp| qp.post_write_u64(0, i + 1)).is_ok() {
                landed += 1;
                assert_eq!(local.load_u64(0), i + 1);
            }
        }
        assert!(landed >= 60, "landed={landed}");
        let stats = fabric.fault_stats().unwrap();
        assert!(stats.verb_retries > 0, "retries must be accounted");

        // Total loss: the budget exhausts, the final VerbLost surfaces,
        // and exactly ATTEMPTS-1 retries were spent.
        let before = fabric.fault_stats().unwrap().verb_retries;
        fabric.set_config(FabricConfig {
            latency: None,
            faults: Some(FaultPlan { verb_loss_prob: 1.0, ..Default::default() }),
            ..Default::default()
        });
        let r = retry_verb(&qp, 7, |qp| qp.post_write_u64(0, 1));
        assert!(matches!(r, Err(RdmaError::VerbLost)));
        assert_eq!(
            fabric.fault_stats().unwrap().verb_retries - before,
            (VERB_RETRY_ATTEMPTS - 1) as u64
        );
    }

    #[test]
    fn retry_verb_does_not_retry_partitions() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        fabric.start_partition(1, 0); // cut everything
        let r = retry_verb(&qp, 1, |qp| qp.post_write_u64(0, 1));
        assert!(matches!(r, Err(RdmaError::Partitioned(_))));
        assert_eq!(
            fabric.fault_stats().unwrap().verb_retries,
            0,
            "a cut link fails fast, no retry budget burned"
        );
        fabric.heal_partition();
    }

    #[test]
    fn traffic_counters() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        qp.post_write(0, &[0u8; 32]).unwrap();
        qp.post_read_u64(0).unwrap();
        let (ops, bytes) = fabric.traffic();
        assert_eq!(ops, 2);
        assert_eq!(bytes, 40);
    }
}
