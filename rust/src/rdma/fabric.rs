//! The fabric: region registry, queue pairs, latency model, fault
//! injection.
//!
//! Latency is *modelled*: every op returns an [`OpOutcome`] carrying the
//! simulated fabric time. [`WaitMode`] controls whether the caller is also
//! physically delayed (`Spin` for latency-sensitive benches, `None` for
//! functional serving runs where only the returned simulated time is
//! used). This is the substitution boundary: swap this file for real ibv
//! verbs and nothing above changes.

use super::region::{MemoryRegion, RegionId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Latency model for one-sided ops: `base_ns + bytes * ns_per_kib / 1024`.
///
/// Defaults model 100 Gb/s InfiniBand: ~2 µs one-way setup plus
/// 12.5 GB/s line rate (0.08 ns/byte).
#[derive(Debug, Clone, Copy)]
pub struct LatencyModel {
    /// Fixed per-op cost (NIC doorbell + propagation), nanoseconds.
    pub base_ns: u64,
    /// Per-byte transfer cost in femtoseconds (1e-6 ns) to keep integer math.
    pub fs_per_byte: u64,
}

impl LatencyModel {
    /// 100 Gb/s InfiniBand-class fabric.
    pub fn infiniband_100g() -> Self {
        Self {
            base_ns: 2_000,
            fs_per_byte: 80_000, // 0.08 ns/byte = 12.5 GB/s
        }
    }

    /// Datacenter TCP-over-Ethernet-class path, for the §6 comparison:
    /// kernel stack + copies dominate (~30 µs base, ~2.5 GB/s effective).
    pub fn tcp_datacenter() -> Self {
        Self {
            base_ns: 30_000,
            fs_per_byte: 400_000, // 0.4 ns/byte = 2.5 GB/s
        }
    }

    /// Simulated duration of transferring `bytes`.
    pub fn duration_ns(&self, bytes: usize) -> u64 {
        self.base_ns + (bytes as u64 * self.fs_per_byte) / 1_000_000
    }
}

/// Whether modelled latency also physically delays the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WaitMode {
    /// Ops complete immediately; simulated time is only reported.
    #[default]
    None,
    /// Spin for the modelled duration (µs-accurate; for latency benches).
    Spin,
}

/// Fabric configuration.
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// Latency model; `None` = ideal fabric (0 ns).
    pub latency: Option<LatencyModel>,
    pub wait: WaitMode,
    /// Probability a `post_write` is silently dropped (message-loss
    /// injection for the §9 no-retransmission tests). Control-plane ops
    /// (CAS/read) are never dropped — they complete or the QP breaks.
    pub write_drop_prob: f64,
    /// Deterministic seed for the drop process.
    pub seed: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self {
            latency: Some(LatencyModel::infiniband_100g()),
            wait: WaitMode::None,
            write_drop_prob: 0.0,
            seed: 0x0EEB_5EED,
        }
    }
}

/// Error surface of the simulated verbs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RdmaError {
    UnknownRegion(RegionId),
    OutOfBounds { off: usize, len: usize, region_len: usize },
}

impl std::fmt::Display for RdmaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RdmaError::UnknownRegion(id) => write!(f, "unknown region {id:?}"),
            RdmaError::OutOfBounds { off, len, region_len } => {
                write!(f, "rdma op out of bounds: off={off} len={len} region={region_len}")
            }
        }
    }
}

impl std::error::Error for RdmaError {}

/// Result of a completed one-sided op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpOutcome {
    /// Modelled fabric time for this op.
    pub simulated_ns: u64,
    /// False if the op was dropped by fault injection (writes only).
    pub delivered: bool,
}

/// The simulated RDMA network. Cheap to clone; regions are shared.
#[derive(Clone, Default)]
pub struct Fabric {
    inner: Arc<FabricInner>,
}

#[derive(Default)]
struct FabricInner {
    regions: Mutex<HashMap<RegionId, MemoryRegion>>, // lint: lock-rank(fabric_regions, 80)
    next_id: AtomicU64,
    config: Mutex<FabricConfig>, // lint: lock-rank(fabric_config, 81)
    // Hot-path mirror of `config` (EXPERIMENTS.md §Perf: a Mutex lock per
    // verb — 12 verbs per ring push before the e15 coalescing, ~6 after —
    // dominated small-message cost).
    hot_latency_on: std::sync::atomic::AtomicBool,
    hot_base_ns: AtomicU64,
    hot_fs_per_byte: AtomicU64,
    hot_wait_spin: std::sync::atomic::AtomicBool,
    hot_drop_bits: AtomicU64, // f64 bits; 0.0 = no drops
    rng_state: AtomicU64,
    /// Total simulated fabric-time and op/byte counters (for benches).
    sim_ns_total: AtomicU64,
    ops_total: AtomicU64,
    bytes_total: AtomicU64,
}

impl Fabric {
    /// New fabric with the given config.
    pub fn new(config: FabricConfig) -> Self {
        let f = Self::default();
        f.inner.rng_state.store(config.seed | 1, Ordering::Relaxed);
        f.apply_hot(&config);
        *f.inner.config.lock().unwrap() = config;
        f
    }

    /// Mirror config fields into the lock-free hot path.
    fn apply_hot(&self, config: &FabricConfig) {
        self.inner
            .hot_latency_on
            .store(config.latency.is_some(), Ordering::Relaxed);
        if let Some(m) = config.latency {
            self.inner.hot_base_ns.store(m.base_ns, Ordering::Relaxed);
            self.inner.hot_fs_per_byte.store(m.fs_per_byte, Ordering::Relaxed);
        }
        self.inner
            .hot_wait_spin
            .store(config.wait == WaitMode::Spin, Ordering::Relaxed);
        self.inner
            .hot_drop_bits
            .store(config.write_drop_prob.to_bits(), Ordering::Relaxed);
    }

    /// New ideal fabric (no latency model, no faults).
    pub fn ideal() -> Self {
        Self::new(FabricConfig {
            latency: None,
            ..Default::default()
        })
    }

    /// Register a memory region of `len_bytes`; returns its fabric id.
    pub fn register(&self, len_bytes: usize) -> (RegionId, MemoryRegion) {
        let id = RegionId(self.inner.next_id.fetch_add(1, Ordering::Relaxed));
        let region = MemoryRegion::new(len_bytes);
        self.inner.regions.lock().unwrap().insert(id, region.clone());
        (id, region)
    }

    /// Deregister a region: subsequent [`Fabric::connect`] /
    /// [`Fabric::local`] calls return `UnknownRegion`. Existing queue
    /// pairs keep their (now orphaned) mapping — exactly the window a
    /// real NIC gives between memory deregistration and QP teardown —
    /// which is why the rendezvous path validates generation + checksum
    /// on every pull instead of trusting connectivity. Returns `false`
    /// if the region was never registered (or already deregistered).
    pub fn deregister(&self, id: RegionId) -> bool {
        self.inner.regions.lock().unwrap().remove(&id).is_some()
    }

    /// Open a queue pair to a registered region ("connect").
    pub fn connect(&self, id: RegionId) -> Result<QueuePair, RdmaError> {
        let region = self
            .inner
            .regions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(RdmaError::UnknownRegion(id))?;
        Ok(QueuePair {
            fabric: self.clone(),
            region,
            region_id: id,
        })
    }

    /// Direct (local) handle to a region — the co-located consumer path.
    pub fn local(&self, id: RegionId) -> Result<MemoryRegion, RdmaError> {
        self.inner
            .regions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(RdmaError::UnknownRegion(id))
    }

    /// Total simulated fabric time accumulated across all ops.
    pub fn simulated_ns(&self) -> u64 {
        self.inner.sim_ns_total.load(Ordering::Relaxed)
    }

    /// (ops, bytes) totals.
    pub fn traffic(&self) -> (u64, u64) {
        (
            self.inner.ops_total.load(Ordering::Relaxed),
            self.inner.bytes_total.load(Ordering::Relaxed),
        )
    }

    /// Update the fault/latency config at runtime (tests).
    pub fn set_config(&self, config: FabricConfig) {
        self.apply_hot(&config);
        *self.inner.config.lock().unwrap() = config;
    }

    fn account(&self, bytes: usize) -> u64 {
        let ns = if self.inner.hot_latency_on.load(Ordering::Relaxed) {
            let base = self.inner.hot_base_ns.load(Ordering::Relaxed);
            let fs = self.inner.hot_fs_per_byte.load(Ordering::Relaxed);
            base + (bytes as u64 * fs) / 1_000_000
        } else {
            0
        };
        self.inner.sim_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.inner.ops_total.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        if ns > 0 && self.inner.hot_wait_spin.load(Ordering::Relaxed) {
            let start = std::time::Instant::now();
            while (start.elapsed().as_nanos() as u64) < ns {
                std::hint::spin_loop();
            }
        }
        ns
    }

    /// xorshift64* over shared state — deterministic drop decisions.
    fn roll_drop(&self) -> bool {
        let prob = f64::from_bits(self.inner.hot_drop_bits.load(Ordering::Relaxed));
        if prob <= 0.0 {
            return false;
        }
        let mut x = self.inner.rng_state.load(Ordering::Relaxed);
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.inner.rng_state.store(x, Ordering::Relaxed);
        ((x >> 11) as f64 / (1u64 << 53) as f64) < prob
    }
}

/// A connected queue pair: one-sided verbs against one remote region.
/// The remote CPU never executes any code for these ops.
#[derive(Clone)]
pub struct QueuePair {
    fabric: Fabric,
    region: MemoryRegion,
    region_id: RegionId,
}

impl QueuePair {
    /// Remote region id this QP is connected to.
    pub fn region_id(&self) -> RegionId {
        self.region_id
    }

    fn check(&self, off: usize, len: usize) -> Result<(), RdmaError> {
        if off + len > self.region.len() {
            return Err(RdmaError::OutOfBounds {
                off,
                len,
                region_len: self.region.len(),
            });
        }
        Ok(())
    }

    /// One-sided RDMA WRITE of `data` at remote byte offset `off`.
    pub fn post_write(&self, off: usize, data: &[u8]) -> Result<OpOutcome, RdmaError> {
        self.check(off, data.len())?;
        let simulated_ns = self.fabric.account(data.len());
        if self.fabric.roll_drop() {
            return Ok(OpOutcome { simulated_ns, delivered: false });
        }
        self.region.write_bytes(off, data);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// One-sided RDMA READ of `out.len()` bytes from remote offset `off`.
    pub fn post_read(&self, off: usize, out: &mut [u8]) -> Result<OpOutcome, RdmaError> {
        self.check(off, out.len())?;
        let simulated_ns = self.fabric.account(out.len());
        self.region.read_bytes(off, out);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Remote atomic 64-bit read.
    pub fn post_read_u64(&self, off: usize) -> Result<(u64, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let simulated_ns = self.fabric.account(8);
        Ok((self.region.load_u64(off), OpOutcome { simulated_ns, delivered: true }))
    }

    /// Remote atomic 64-bit write.
    pub fn post_write_u64(&self, off: usize, v: u64) -> Result<OpOutcome, RdmaError> {
        self.check(off, 8)?;
        let simulated_ns = self.fabric.account(8);
        self.region.store_u64(off, v);
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// RDMA Compare-and-Swap verb. Returns `Ok(prev)` on success,
    /// `Err(prev)` on mismatch (both after fabric delay).
    pub fn post_cas(
        &self,
        off: usize,
        expected: u64,
        new: u64,
    ) -> Result<(Result<u64, u64>, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let simulated_ns = self.fabric.account(8);
        Ok((
            self.region.cas_u64(off, expected, new),
            OpOutcome { simulated_ns, delivered: true },
        ))
    }

    /// RDMA Fetch-and-Add verb.
    pub fn post_fetch_add(&self, off: usize, v: u64) -> Result<(u64, OpOutcome), RdmaError> {
        self.check(off, 8)?;
        let simulated_ns = self.fabric.account(8);
        Ok((
            self.region.fetch_add_u64(off, v),
            OpOutcome { simulated_ns, delivered: true },
        ))
    }

    /// Vectored read of `out.len()` contiguous 64-bit words starting at
    /// word-aligned `off`, charged as **one** verb (`base_ns` + 8·n
    /// bytes). Each word is loaded with the same atomic semantics as
    /// [`QueuePair::post_read_u64`]. This is the GH header-snapshot op:
    /// on real hardware it is a single READ work request covering the
    /// contiguous header words — one doorbell, one completion — instead
    /// of n separate verbs.
    pub fn post_read_words(&self, off: usize, out: &mut [u64]) -> Result<OpOutcome, RdmaError> {
        self.check(off, out.len() * 8)?;
        let simulated_ns = self.fabric.account(out.len() * 8);
        for (i, w) in out.iter_mut().enumerate() {
            *w = self.region.load_u64(off + i * 8);
        }
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Vectored write of contiguous 64-bit words at word-aligned `off`,
    /// charged as one verb. Control-plane (header) op: like
    /// [`QueuePair::post_write_u64`] it is never dropped by fault
    /// injection — it completes or the QP breaks.
    pub fn post_write_words(&self, off: usize, vals: &[u64]) -> Result<OpOutcome, RdmaError> {
        self.check(off, vals.len() * 8)?;
        let simulated_ns = self.fabric.account(vals.len() * 8);
        for (i, v) in vals.iter().enumerate() {
            self.region.store_u64(off + i * 8, *v);
        }
        Ok(OpOutcome { simulated_ns, delivered: true })
    }

    /// Two CAS work requests posted with a **single doorbell**, charged
    /// as one verb. Both execute in posting order with independent
    /// compare semantics (a doorbell batch on a real QP: the WRs share
    /// the PCIe round trip and completion, not their atomicity). Used by
    /// the ring's UH step to advance both tail words for one `base_ns`.
    #[allow(clippy::too_many_arguments)]
    #[allow(clippy::type_complexity)]
    pub fn post_cas_pair(
        &self,
        off1: usize,
        expected1: u64,
        new1: u64,
        off2: usize,
        expected2: u64,
        new2: u64,
    ) -> Result<((Result<u64, u64>, Result<u64, u64>), OpOutcome), RdmaError> {
        self.check(off1, 8)?;
        self.check(off2, 8)?;
        let simulated_ns = self.fabric.account(16);
        let r1 = self.region.cas_u64(off1, expected1, new1);
        let r2 = self.region.cas_u64(off2, expected2, new2);
        Ok(((r1, r2), OpOutcome { simulated_ns, delivered: true }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_connect_write_read() {
        let fabric = Fabric::ideal();
        let (id, local) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        qp.post_write(0, b"hello RDMA pad.").unwrap();
        let mut out = vec![0u8; 15];
        qp.post_read(0, &mut out).unwrap();
        assert_eq!(&out, b"hello RDMA pad.");
        // The write is visible to the co-located owner without any CPU
        // involvement on the "remote" side.
        let mut direct = vec![0u8; 5];
        local.read_bytes(0, &mut direct);
        assert_eq!(&direct, b"hello");
    }

    #[test]
    fn deregister_models_producer_death() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(64);
        // A QP opened before death keeps working (NIC teardown window)…
        let qp = fabric.connect(id).unwrap();
        assert!(fabric.deregister(id));
        assert!(qp.post_write(0, &[1u8; 8]).is_ok());
        // …but new connects and locals see the region gone.
        assert!(matches!(fabric.connect(id), Err(RdmaError::UnknownRegion(_))));
        assert!(matches!(fabric.local(id), Err(RdmaError::UnknownRegion(_))));
        assert!(!fabric.deregister(id), "double deregister is a no-op");
    }

    #[test]
    fn unknown_region_rejected() {
        let fabric = Fabric::ideal();
        assert!(matches!(
            fabric.connect(RegionId(99)),
            Err(RdmaError::UnknownRegion(_))
        ));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        assert!(qp.post_write(8, &[1]).is_err());
    }

    #[test]
    fn cas_verb() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        let (r, _) = qp.post_cas(0, 0, 5).unwrap();
        assert_eq!(r, Ok(0));
        let (r, _) = qp.post_cas(0, 0, 6).unwrap();
        assert_eq!(r, Err(5));
    }

    #[test]
    fn latency_model_accounts() {
        let fabric = Fabric::new(FabricConfig {
            latency: Some(LatencyModel::infiniband_100g()),
            ..Default::default()
        });
        let (id, _) = fabric.register(1 << 20);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write(0, &vec![0u8; 1 << 20]).unwrap();
        // 2µs + 1MiB * 0.08 ns/B ≈ 85.9 µs
        assert!(out.simulated_ns > 80_000 && out.simulated_ns < 95_000,
                "ns={}", out.simulated_ns);
        assert_eq!(fabric.simulated_ns(), out.simulated_ns);
    }

    #[test]
    fn tcp_slower_than_rdma_model() {
        let rdma = LatencyModel::infiniband_100g();
        let tcp = LatencyModel::tcp_datacenter();
        for bytes in [0usize, 4096, 1 << 20, 64 << 20] {
            assert!(tcp.duration_ns(bytes) > rdma.duration_ns(bytes));
        }
    }

    #[test]
    fn write_drop_injection() {
        let fabric = Fabric::new(FabricConfig {
            latency: None,
            write_drop_prob: 1.0,
            ..Default::default()
        });
        let (id, local) = fabric.register(8);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write(0, &[0xAB; 8]).unwrap();
        assert!(!out.delivered);
        assert_eq!(local.load_u64(0), 0, "dropped write must not land");
        // CAS is control-plane: never dropped.
        let (r, _) = qp.post_cas(0, 0, 1).unwrap();
        assert_eq!(r, Ok(0));
    }

    #[test]
    fn vectored_words_roundtrip_as_one_verb() {
        let fabric = Fabric::new(FabricConfig {
            latency: Some(LatencyModel::infiniband_100g()),
            ..Default::default()
        });
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        let out = qp.post_write_words(16, &[7, 8, 9]).unwrap();
        // One verb: one base_ns, not three.
        assert!(out.simulated_ns < 2 * LatencyModel::infiniband_100g().base_ns);
        let mut words = [0u64; 3];
        let out = qp.post_read_words(16, &mut words).unwrap();
        assert_eq!(words, [7, 8, 9]);
        assert!(out.simulated_ns < 2 * LatencyModel::infiniband_100g().base_ns);
        let (ops, bytes) = fabric.traffic();
        assert_eq!(ops, 2, "a vectored op is a single verb");
        assert_eq!(bytes, 48);
        // Bounds still enforced.
        assert!(qp.post_read_words(56, &mut words).is_err());
    }

    #[test]
    fn cas_pair_independent_compares_one_verb() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(32);
        let qp = fabric.connect(id).unwrap();
        qp.post_write_u64(8, 5).unwrap();
        // First CAS matches, second does not: independent outcomes.
        let ((r1, r2), _) = qp.post_cas_pair(0, 0, 1, 8, 0, 2).unwrap();
        assert_eq!(r1, Ok(0));
        assert_eq!(r2, Err(5));
        let (ops, _) = fabric.traffic();
        assert_eq!(ops, 1, "a doorbell-batched CAS pair is one verb");
    }

    #[test]
    fn traffic_counters() {
        let fabric = Fabric::ideal();
        let (id, _) = fabric.register(64);
        let qp = fabric.connect(id).unwrap();
        qp.post_write(0, &[0u8; 32]).unwrap();
        qp.post_read_u64(0).unwrap();
        let (ops, bytes) = fabric.traffic();
        assert_eq!(ops, 2);
        assert_eq!(bytes, 40);
    }
}
