//! Simulated one-sided RDMA fabric (DESIGN.md §2 "RDMA substitution").
//!
//! The paper's Workflow Sets communicate over InfiniBand with **one-sided
//! verbs**: the sender names a remote address and the remote CPU is never
//! involved (§2.1, §6). This module reproduces exactly that contract in
//! software so every protocol above it (the double-ring buffer, message
//! delivery, DB replication) runs unchanged:
//!
//! - [`MemoryRegion`] — a registered, fixed-size memory region addressable
//!   by byte offset, with atomic 64-bit words for control fields (the
//!   verbs `CompareAndSwap` / `FetchAdd` equivalents).
//! - [`QueuePair`] — a connected handle through which a *remote* peer
//!   issues `post_write` / `post_read` / `post_cas` / `post_fetch_add`.
//!   Ops execute against the region memory directly — no code runs on the
//!   "remote CPU" — after an optional modelled fabric delay.
//! - [`Fabric`] — registry of regions plus the latency/loss model
//!   (default calibrated to 100 Gb/s InfiniBand: ~2 µs base + 1/12.5 GB/s
//!   per byte) and fault injection used by the liveness tests.
//!
//! What is and is not faithful: one-sidedness, CAS atomicity, per-QP
//! ordering and sender loss mid-protocol are reproduced; absolute latency
//! is *modelled* (returned as simulated ns per op) rather than enforced by
//! real hardware. See DESIGN.md for why this preserves the evaluated
//! behavior.

mod fabric;
mod region;

pub use fabric::{
    retry_verb, Fabric, FabricConfig, FaultPlan, FaultStats, LatencyModel, OpOutcome, QueuePair,
    RdmaError, WaitMode, VERB_RETRY_ATTEMPTS, VERB_RETRY_DEADLINE,
};
pub use region::{
    MemoryRegion, PayloadDescriptor, PayloadStager, RegionId, PAYLOAD_DESC_BYTES,
    PAYLOAD_GEN_OFF, PAYLOAD_HDR_BYTES, PAYLOAD_RELEASE_OFF,
};
