//! Offline shim for the [`anyhow`](https://docs.rs/anyhow) error crate.
//!
//! The container this repository builds in has no crates.io access, so
//! this vendored crate provides the (small) subset of the anyhow API the
//! workspace actually uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values carry a flattened context chain
//! (outermost first) rather than a live `source()` chain — enough for
//! the human-readable diagnostics this project needs.

use std::error::Error as StdError;
use std::fmt::{self, Display};

/// A flattened error: the head message plus the chain of causes.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages below the head, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Sealed conversion implemented for std errors *and* [`Error`] itself,
/// so [`Context`] has a single non-overlapping blanket impl.
pub trait IntoError: private::Sealed {
    #[doc(hidden)]
    fn into_error(self) -> Error;
}

mod private {
    pub trait Sealed {}
    impl<E: std::error::Error + Send + Sync + 'static> Sealed for E {}
    impl Sealed for super::Error {}
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily evaluated context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> std::num::ParseIntError {
        "nope".parse::<i32>().unwrap_err()
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }

    #[test]
    fn context_chains() {
        let e: Result<()> = Err(parse_err()).context("reading config");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_option_and_own_error() {
        let none: Option<u8> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        let wrapped: Result<()> = Err(e).context("loading manifest");
        let w = wrapped.unwrap_err();
        assert_eq!(w.to_string(), "loading manifest");
        assert_eq!(w.root_cause(), "missing field");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(101).unwrap_err().to_string(), "x too large: 101");
        let e = anyhow!("standalone {}", 7);
        assert_eq!(e.to_string(), "standalone 7");
    }
}
